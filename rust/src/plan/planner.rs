//! Candidate enumeration + cost-model pricing for deployment plans.
//!
//! Conv/pcap candidates are priced by replaying the real kernels' event
//! emissions from geometry alone; capsule layers by executing the routing
//! kernel on zero operands. Conv event counts are data-independent, so the
//! strategy ranking equals what metered execution on live data produces
//! (property-tested below); sharing the kernels' emission code guarantees
//! the estimator can never drift from the engine.

use super::memory::MemoryMap;
use super::{
    CandidateCost, DeploymentPlan, LayerKind, LayerPlan, PlanIsa, StrategyChoice, PLAN_VERSION,
};
use crate::coordinator::{BatchPolicy, DEFAULT_BATCH_CAPACITY};
use crate::isa::{Board, ClusterRun, CostModel, CycleCounter, Isa};
use crate::kernels::capsule::{
    capsule_layer_q7_arm_ws, capsule_layer_q7_riscv_ws, CapsuleDims, CapsuleShifts,
};
use crate::kernels::conv::{
    emit_arm_conv_events, emit_pulp_conv_events, ConvDims, PulpConvStrategy,
};
use crate::kernels::pcap::PcapDims;
use crate::model::CapsNetConfig;

/// Planner knobs.
#[derive(Clone, Copy, Debug)]
pub struct PlanOptions {
    /// Batch size the resident arena is sized for (and the upper bound on
    /// the adaptive batch policy).
    pub batch_capacity: usize,
    /// Latency budget the batch policy must respect: batch members run
    /// back-to-back on the device, so a batch of `n` delays its first
    /// member by up to `(n-1) ×` the inference latency.
    pub slo_ms: f64,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions { batch_capacity: DEFAULT_BATCH_CAPACITY, slo_ms: 50.0 }
    }
}

/// Build the deployment plan for `config` on `board`: per-layer strategy
/// autotuning under the board's calibrated cycle model, the batched-arena
/// memory map, and an adaptive batch policy for the board's speed class.
pub fn plan_deployment(
    config: &CapsNetConfig,
    board: &Board,
    opts: &PlanOptions,
) -> DeploymentPlan {
    let cost = board.cost_model();
    let batch_capacity = opts.batch_capacity.max(1);
    let mut layers = Vec::new();
    for i in 0..config.conv_layers.len() {
        layers.push(plan_conv_layer(
            format!("conv{i}"),
            LayerKind::Conv,
            &config.conv_dims(i),
            true,
            &cost,
            board.n_cores,
        ));
    }
    layers.push(plan_pcap_layer(&config.pcap_dims(), &cost, board.n_cores));
    for i in 0..config.caps_layers.len() {
        layers.push(plan_caps_layer(
            format!("caps{i}"),
            &config.caps_dims(i),
            config.caps_layers[i].routings,
            &cost,
            board.n_cores,
        ));
    }
    let predicted_cycles: u64 = layers.iter().map(|l| l.predicted_cycles).sum();
    let predicted_ms = board.cycles_to_ms(predicted_cycles);
    let policy = BatchPolicy::for_device_speed(predicted_ms, opts.slo_ms, batch_capacity);
    DeploymentPlan {
        plan_version: PLAN_VERSION,
        model: config.name.clone(),
        board: board.name.to_string(),
        isa: PlanIsa::from_isa(cost.isa),
        batch_capacity,
        batch_window_ms: policy.window_ms,
        batch_max: policy.max_batch,
        layers,
        memory: MemoryMap::for_deployment(config, board, batch_capacity),
        predicted_cycles,
        predicted_ms,
    }
}

/// The PULP conv strategy candidate set, incumbent default (`HoWo`) first
/// so cost ties keep today's pinned behavior. The single source for both
/// the conv-layer and pcap-layer enumerations — a new strategy added here
/// is automatically priced everywhere.
const PULP_CANDIDATES: [PulpConvStrategy; 3] =
    [PulpConvStrategy::HoWo, PulpConvStrategy::Co, PulpConvStrategy::Ho];

/// Power-of-two core splits available on a cluster of `n` cores, largest
/// first so ties prefer the full cluster.
fn core_splits(n: usize) -> impl Iterator<Item = usize> {
    [16usize, 8, 4, 2, 1].into_iter().filter(move |&c| c <= n)
}

/// The core count execution will actually use: the full cluster on RISC-V
/// (Arm boards are single-core). `core_splits` always includes it.
fn exec_cores(cost: &CostModel, n_cores: usize) -> usize {
    match cost.isa {
        Isa::RiscvXpulp => n_cores,
        _ => 1,
    }
}

/// Pick the cheapest candidate **at the executed core count**. Execution
/// runs the whole forward on one cluster configuration (per-layer core
/// splits are a ROADMAP follow-on), so choosing a sub-cluster candidate
/// the engine cannot honor could silently invert the planned-vs-pinned
/// guarantee within the fork/join margin; sub-cluster candidates stay in
/// the table for auditability and for that follow-on. `candidates` are
/// enumerated in preference order (incumbent default first), so a strict
/// `<` keeps ties on the earlier entry — plans stay stable when costs are
/// equal.
fn pick(candidates: &[CandidateCost], exec_cores: usize) -> CandidateCost {
    let mut best: Option<CandidateCost> = None;
    for &c in candidates {
        if c.cores == exec_cores && best.is_none_or(|b| c.cycles < b.cycles) {
            best = Some(c);
        }
    }
    best.expect("candidate set covers the executed core count")
}

fn layer_from(
    name: String,
    kind: LayerKind,
    candidates: Vec<CandidateCost>,
    exec_cores: usize,
) -> LayerPlan {
    let chosen = pick(&candidates, exec_cores);
    LayerPlan {
        name,
        kind,
        choice: chosen.choice,
        cores: chosen.cores,
        predicted_cycles: chosen.cycles,
        candidates,
    }
}

fn plan_conv_layer(
    name: String,
    kind: LayerKind,
    d: &ConvDims,
    relu: bool,
    cost: &CostModel,
    n_cores: usize,
) -> LayerPlan {
    let mut candidates = Vec::new();
    match cost.isa {
        Isa::RiscvXpulp => {
            for strat in PULP_CANDIDATES {
                for cores in core_splits(n_cores) {
                    candidates.push(CandidateCost {
                        choice: StrategyChoice::from_pulp(strat),
                        cores,
                        cycles: meter_pulp_conv(cost, d, strat, cores),
                    });
                }
            }
        }
        _ => {
            if d.in_ch % 4 == 0 && d.out_ch % 2 == 0 {
                candidates.push(CandidateCost {
                    choice: StrategyChoice::ArmFast,
                    cores: 1,
                    cycles: meter_arm_conv(cost, d, relu, true),
                });
            }
            candidates.push(CandidateCost {
                choice: StrategyChoice::ArmBasic,
                cores: 1,
                cycles: meter_arm_conv(cost, d, relu, false),
            });
        }
    }
    layer_from(name, kind, candidates, exec_cores(cost, n_cores))
}

fn plan_pcap_layer(pd: &PcapDims, cost: &CostModel, n_cores: usize) -> LayerPlan {
    let mut candidates = Vec::new();
    match cost.isa {
        Isa::RiscvXpulp => {
            for strat in PULP_CANDIDATES {
                for cores in core_splits(n_cores) {
                    candidates.push(CandidateCost {
                        choice: StrategyChoice::from_pulp(strat),
                        cores,
                        cycles: meter_pulp_pcap(cost, pd, strat, cores),
                    });
                }
            }
        }
        _ => {
            if pd.conv.in_ch % 4 == 0 && pd.conv.out_ch % 2 == 0 {
                candidates.push(CandidateCost {
                    choice: StrategyChoice::ArmFast,
                    cores: 1,
                    cycles: meter_arm_pcap(cost, pd, true),
                });
            }
            candidates.push(CandidateCost {
                choice: StrategyChoice::ArmBasic,
                cores: 1,
                cycles: meter_arm_pcap(cost, pd, false),
            });
        }
    }
    layer_from("pcap".to_string(), LayerKind::Pcap, candidates, exec_cores(cost, n_cores))
}

fn plan_caps_layer(
    name: String,
    d: &CapsuleDims,
    routings: usize,
    cost: &CostModel,
    n_cores: usize,
) -> LayerPlan {
    let mut candidates = Vec::new();
    match cost.isa {
        Isa::RiscvXpulp => {
            // No kernel alternatives for dynamic routing — only core splits.
            for cores in core_splits(n_cores) {
                candidates.push(CandidateCost {
                    choice: StrategyChoice::Routing,
                    cores,
                    cycles: meter_riscv_caps(cost, d, routings, cores),
                });
            }
        }
        _ => {
            candidates.push(CandidateCost {
                choice: StrategyChoice::Routing,
                cores: 1,
                cycles: meter_arm_caps(cost, d, routings),
            });
        }
    }
    layer_from(name, LayerKind::Caps, candidates, exec_cores(cost, n_cores))
}

// -- candidate pricing ------------------------------------------------------
//
// Conv and pcap candidates are priced by replaying the kernels' exact event
// emissions from geometry alone (`emit_*_conv_events` — property-tested
// equal to executed kernels), so pricing costs microseconds instead of a
// full functional pass. The pcap rows price the strategy-*dependent*
// convolution; the squash add-on is strategy-invariant and cancels in the
// argmin (and in candidate deltas — tested below). Capsule layers are
// priced by executing the real routing kernel on zero operands (cheap, and
// there is no strategy choice to rank — only core splits).

fn meter_arm_conv(cost: &CostModel, d: &ConvDims, relu: bool, fast: bool) -> u64 {
    let mut cc = CycleCounter::new(cost.clone());
    emit_arm_conv_events(d, relu, fast, &mut cc);
    cc.cycles()
}

fn meter_pulp_conv(cost: &CostModel, d: &ConvDims, strat: PulpConvStrategy, cores: usize) -> u64 {
    let mut run = ClusterRun::new(cost, cores);
    emit_pulp_conv_events(d, strat, &mut run);
    run.cycles()
}

fn meter_arm_pcap(cost: &CostModel, pd: &PcapDims, fast: bool) -> u64 {
    // The pcap convolution runs without ReLU (capsule outputs are signed).
    meter_arm_conv(cost, &pd.conv, false, fast)
}

fn meter_pulp_pcap(cost: &CostModel, pd: &PcapDims, strat: PulpConvStrategy, cores: usize) -> u64 {
    meter_pulp_conv(cost, &pd.conv, strat, cores)
}

fn meter_arm_caps(cost: &CostModel, d: &CapsuleDims, routings: usize) -> u64 {
    let u = vec![0i8; d.input_len()];
    let w = vec![0i8; d.weight_len()];
    let shifts = CapsuleShifts::uniform(routings, 7, 5);
    let mut out = vec![0i8; d.output_len()];
    let mut scratch = vec![0i8; d.scratch_len()];
    let mut cc = CycleCounter::new(cost.clone());
    capsule_layer_q7_arm_ws(&u, &w, d, routings, &shifts, &mut scratch, &mut out, &mut cc);
    cc.cycles()
}

fn meter_riscv_caps(cost: &CostModel, d: &CapsuleDims, routings: usize, cores: usize) -> u64 {
    let u = vec![0i8; d.input_len()];
    let w = vec![0i8; d.weight_len()];
    let shifts = CapsuleShifts::uniform(routings, 7, 5);
    let mut out = vec![0i8; d.output_len()];
    let mut scratch = vec![0i8; d.scratch_len()];
    let mut run = ClusterRun::new(cost, cores);
    capsule_layer_q7_riscv_ws(&u, &w, d, routings, &shifts, &mut scratch, &mut out, &mut run);
    run.cycles()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::NullMeter;
    use crate::kernels::pcap::{pcap_q7_pulp, PcapShifts};
    use crate::kernels::squash::SquashParams;
    use crate::model::{configs, ArmConv, QuantizedCapsNet};
    use crate::testing::prop::XorShift;

    fn gap8_plan(cfg: &CapsNetConfig) -> DeploymentPlan {
        plan_deployment(cfg, &Board::gapuino(), &PlanOptions::default())
    }

    fn pcap_layer(plan: &DeploymentPlan) -> &LayerPlan {
        plan.layers.iter().find(|l| l.kind == LayerKind::Pcap).unwrap()
    }

    #[test]
    fn chosen_candidate_is_the_argmin_at_executed_cores() {
        for cfg in configs::all() {
            for board in [Board::stm32h755(), Board::gapuino()] {
                let plan = plan_deployment(&cfg, &board, &PlanOptions::default());
                let exec = board.n_cores;
                for l in &plan.layers {
                    assert_eq!(l.cores, exec, "{} {}", cfg.name, l.name);
                    let min = l
                        .candidates
                        .iter()
                        .filter(|c| c.cores == exec)
                        .map(|c| c.cycles)
                        .min()
                        .unwrap();
                    assert_eq!(l.predicted_cycles, min, "{} {}", cfg.name, l.name);
                    let listed =
                        l.candidates.iter().any(|c| c.choice == l.choice && c.cores == l.cores);
                    assert!(listed, "{} {}: choice missing from candidates", cfg.name, l.name);
                }
            }
        }
    }

    #[test]
    fn cifar_pcap_prefers_a_non_howo_strategy() {
        // Acceptance criterion: on a Table 6 geometry (CIFAR-10 pcap,
        // 3x3x64x64 over 2×2 output pixels) the planner leaves the pinned
        // HoWo default — with only 4 output pixels, splitting pixels over 8
        // cores idles half the cluster, while the Co channel split keeps all
        // 8 busy. The cost model must rank the chosen strategy strictly
        // cheaper than HoWo at the same core count.
        let plan = gap8_plan(&configs::cifar10());
        let l = pcap_layer(&plan);
        assert_ne!(l.choice, StrategyChoice::PulpHoWo, "cifar pcap stayed on HoWo");
        let howo = l
            .candidates
            .iter()
            .find(|c| c.choice == StrategyChoice::PulpHoWo && c.cores == l.cores)
            .unwrap();
        assert!(
            l.predicted_cycles < howo.cycles,
            "chosen {} ({} cycles) not cheaper than HoWo ({})",
            l.choice.as_str(),
            l.predicted_cycles,
            howo.cycles
        );
    }

    #[test]
    fn mnist_pcap_matches_paper_table6_shape() {
        // Paper Table 6 (MNIST ×8): Ho/HoWo essentially tie and both beat
        // Co (Co duplicates the im2col gather per core). Our calibrated
        // model reproduces that shape; the planner must not pick Co.
        //
        // Note the model does not reproduce every Table 6 *winner* — e.g.
        // the paper measures Co best on smallNORB ×8 while the calibrated
        // tables rank HoWo ahead. The planner's contract is argmin under
        // the calibrated model (which equals argmin under metered
        // execution, see the ranking test below), not a table lookup.
        let plan = gap8_plan(&configs::mnist());
        let l = pcap_layer(&plan);
        assert!(
            matches!(l.choice, StrategyChoice::PulpHo | StrategyChoice::PulpHoWo),
            "mnist pcap chose {}",
            l.choice.as_str()
        );
        assert_eq!(l.cores, 8);
    }

    #[test]
    fn candidate_ranking_matches_metered_execution_on_live_data() {
        // The plan prices pcap candidates from geometry alone (conv events
        // only); execution meters live data including the squash. Conv
        // event counts are data-independent and the squash is identical
        // across strategies (they all produce the same conv output), so
        // pairwise candidate *deltas* must match metered execution exactly
        // — for every Table 6 pcap workload at the full core split.
        for cfg in configs::all() {
            let pd = cfg.pcap_dims();
            let plan = gap8_plan(&cfg);
            let l = pcap_layer(&plan);
            let mut rng = XorShift::new(0xCAFE);
            let input = rng.i8_vec(pd.conv.in_len());
            let w = rng.i8_vec(pd.conv.weight_len());
            let bias = rng.i8_vec(pd.conv.out_ch);
            let shifts =
                PcapShifts { bias_shift: 0, out_shift: 7, squash: SquashParams::q7_out(5) };
            let metered = |strat: PulpConvStrategy| {
                let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
                let mut out = vec![0i8; pd.out_len()];
                pcap_q7_pulp(&input, &w, &bias, &pd, shifts, strat, &mut out, &mut run);
                run.cycles() as i64
            };
            let predicted = |strat: PulpConvStrategy| {
                l.candidates
                    .iter()
                    .find(|c| c.choice == StrategyChoice::from_pulp(strat) && c.cores == 8)
                    .unwrap()
                    .cycles as i64
            };
            let (strats, m_howo, p_howo) = (
                [PulpConvStrategy::Co, PulpConvStrategy::Ho],
                metered(PulpConvStrategy::HoWo),
                predicted(PulpConvStrategy::HoWo),
            );
            for s in strats {
                assert_eq!(
                    metered(s) - m_howo,
                    predicted(s) - p_howo,
                    "{}: {:?} delta drifted between planner and execution",
                    cfg.name,
                    s
                );
            }
        }
    }

    #[test]
    fn planned_forward_never_loses_to_pinned_howo() {
        // Full-network metered execution under the planned schedule must be
        // at most the pinned-HoWo cost on every Table 6 workload — HoWo is
        // always in the candidate set, so per-layer argmin can only help.
        for cfg in configs::all() {
            let plan = gap8_plan(&cfg);
            let schedule = plan.riscv_schedule().unwrap();
            let net = QuantizedCapsNet::random(cfg.clone(), 77);
            let mut rng = XorShift::new(78);
            let input = rng.i8_vec(net.config.input_len());
            let mut ws = net.config.workspace();
            let mut out = vec![0i8; net.config.output_len()];
            let mut pinned = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
            net.forward_riscv_into(&input, PulpConvStrategy::HoWo, &mut ws, &mut out, &mut pinned);
            let pinned_out = out.clone();
            let mut planned = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
            net.forward_riscv_scheduled_into(&input, &schedule, &mut ws, &mut out, &mut planned);
            assert_eq!(out, pinned_out, "{}: plan changed the computed function", cfg.name);
            assert!(
                planned.cycles() <= pinned.cycles(),
                "{}: planned {} > pinned {}",
                cfg.name,
                planned.cycles(),
                pinned.cycles()
            );
        }
    }

    #[test]
    fn arm_planner_picks_fast_conv_where_legal() {
        // Table 5: fast beats basic on every legal pcap workload; MNIST's
        // first conv (in_ch = 1) is fast-illegal so only basic is offered.
        let plan = plan_deployment(&configs::mnist(), &Board::stm32h755(), &PlanOptions::default());
        let conv0 = &plan.layers[0];
        assert_eq!(conv0.choice, StrategyChoice::ArmBasic);
        assert_eq!(conv0.candidates.len(), 1);
        let l = pcap_layer(&plan);
        assert_eq!(l.choice, StrategyChoice::ArmFast, "fast pcap should win (Table 5)");
        assert_eq!(l.candidates.len(), 2);
    }

    #[test]
    fn batch_policy_adapts_to_device_speed_class() {
        // ROADMAP "adaptive batch sizing": under the same SLO, the fast
        // GAP-8 gets a large batch, the slow Cortex-M4 a small one.
        let opts = PlanOptions { batch_capacity: 8, slo_ms: 500.0 };
        let cfg = configs::mnist();
        let fast = plan_deployment(&cfg, &Board::gapuino(), &opts);
        let slow = plan_deployment(&cfg, &Board::stm32l4r5(), &opts);
        assert!(
            fast.batch_max > slow.batch_max,
            "gap8 batch {} vs m4 batch {}",
            fast.batch_max,
            slow.batch_max
        );
        assert!(slow.batch_max >= 1);
        assert!(fast.batch_max <= opts.batch_capacity);
    }

    #[test]
    fn arm_and_riscv_plans_execute_bit_identically() {
        // Plan-driven execution on both ISAs still computes the reference
        // function (the planner only repartitions work).
        let cfg = configs::cifar10();
        let net = QuantizedCapsNet::random(cfg.clone(), 5);
        let mut rng = XorShift::new(6);
        let input = rng.i8_vec(net.config.input_len());
        let reference = net.forward_arm(&input, ArmConv::FastWithFallback, &mut NullMeter);

        let arm_plan = plan_deployment(&cfg, &Board::stm32h755(), &PlanOptions::default());
        let mut ws = net.config.workspace();
        let mut out = vec![0i8; net.config.output_len()];
        net.forward_arm_scheduled_into(
            &input, &arm_plan.arm_schedule().unwrap(), &mut ws, &mut out, &mut NullMeter,
        );
        assert_eq!(out, reference);

        let rv_plan = gap8_plan(&cfg);
        let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
        net.forward_riscv_scheduled_into(
            &input, &rv_plan.riscv_schedule().unwrap(), &mut ws, &mut out, &mut run,
        );
        assert_eq!(out, reference);
    }
}
