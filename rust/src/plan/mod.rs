//! Deployment planner: cost-model-driven per-layer strategy autotuning and
//! serialized deployment plans.
//!
//! The paper picks its kernel strategy per workload by hand (Tables 6–8 show
//! the best PULP conv strategy and core split are layer-dependent), and the
//! pre-planner engine pinned one global choice (`PulpConvStrategy::HoWo`,
//! `ArmConv::FastWithFallback`) for every layer. This module makes that
//! decision a first-class, framework-driven step — the Q-CapsNets lesson
//! (Marchisio et al., 2020) that per-layer deployment decisions, not one
//! global setting, make quantized CapsNets viable on constrained targets:
//!
//! 1. [`plan_deployment`] enumerates every *legal* kernel strategy per layer
//!    (Arm basic/fast conv where the channel constraints permit; all
//!    [`PulpConvStrategy`] variants × power-of-two core splits on RISC-V)
//!    and meters each candidate through the calibrated [`crate::isa::cost`]
//!    cycle model, picking the cheapest.
//! 2. The plan carries an exact [`MemoryMap`] of the batched workspace
//!    arena, derived from the `scratch_len_batched` contract the zero-alloc
//!    forward paths carve (ping/pong activation slabs + kernel scratch, in
//!    carver order), plus the staging slabs and the paper-§5 deployment
//!    footprint vs. the board's 80 %-RAM budget.
//! 3. The plan emits an adaptive [`BatchPolicy`](crate::coordinator::BatchPolicy)
//!    sized to the device's speed class (slow boards batch less so the
//!    back-to-back batch delay stays inside the latency SLO).
//! 4. The whole artifact serializes as versioned JSON via [`crate::formats`]
//!    (round-trip tested) and is consumed by
//!    [`Device::apply_plan`](crate::coordinator::Device) and
//!    [`Fleet::serve_planned`](crate::coordinator::Fleet), so execution is
//!    plan-driven instead of hard-coded — with today's pinned defaults as
//!    the fallback when no plan is applied.
//!
//! ## Plan schema (version 3)
//!
//! ```json
//! {
//!   "plan_version": 3,
//!   "model": "cifar10",            // CapsNetConfig::name the plan is for
//!   "board": "GAPuino v1 (GAP-8)", // Board::name the costs were metered on
//!   "isa": "riscv-xpulp",          // arm-v7em | arm-v8m | riscv-xpulp
//!   "batch_capacity": 8,           // resident arena batch size
//!   "batch_policy": {"window_ms": 12.5, "max_batch": 2},
//!   "layers": [
//!     {"name": "conv0", "kind": "conv", "strategy": "pulp-howo", "cores": 8,
//!      "nonlinearity": "exact",    // "approx" only on caps layers
//!      "predicted_cycles": 123456,
//!      "candidates": [{"strategy": "pulp-co", "cores": 8,
//!                      "nonlinearity": "exact", "cycles": 234567}, ...]},
//!     ...
//!   ],
//!   "accuracy": {
//!     "budget": 0.05,              // max tolerated agreement drop per layer
//!     "calibration_images": 16,    // sweep size (0 when budget == 0)
//!     "caps_layer_drops": [0.0]    // measured drop per caps layer, in order
//!   },
//!   "memory": {
//!     "arena_bytes": 131072,
//!     "regions": [{"name": "act_ping", "offset": 0, "bytes": 65536}, ...],
//!     "staging_in_bytes": 24576, "staging_out_bytes": 400,
//!     "model_bytes": 99999, "deployed_bytes": 222222,
//!     "usable_ram_bytes": 419430, "fits": true
//!   },
//!   "predicted_cycles": 3456789,   // sum of per-layer estimates
//!   "predicted_ms": 20.33
//! }
//! ```
//!
//! ## Versioning rules
//!
//! `plan_version` is a single integer bumped on **any** schema change
//! (field rename, semantic change, or addition a loader must understand).
//! Loaders accept exactly [`PLAN_VERSION`] and refuse anything else with an
//! actionable error ("regenerate with `capsnet-edge plan`") — a stale plan
//! silently interpreted under new semantics could deploy the wrong arena
//! size, which on a real MCU is a memory-safety bug, so there is no
//! cross-version compatibility shim.
//!
//! Version history: v1 carried per-layer `cores` as an advisory field (the
//! engine ran one cluster configuration and the planner flattened its
//! choice to the full cluster). v2 makes `cores` **binding**: execution
//! honors each layer's split as its own fork/join section, the planner may
//! emit genuinely mixed splits (ties keep the larger split, incumbent
//! strategy first), and [`DeploymentPlan::validate_for`] rejects splits the
//! target board cannot run (non-power-of-two, larger than the cluster, or
//! any split ≠ 1 on a single-core Arm board). v3 adds the per-layer
//! `nonlinearity` selection (the approximate routing kernels of arXiv
//! 2206.10200 as first-class argmin candidates, admitted only within
//! `PlanOptions::accuracy_budget`) and the `accuracy` metadata block that
//! records the budget and the calibration sweep's measured per-capsule-layer
//! agreement drops; exact plans (budget 0) carry `"nonlinearity": "exact"`
//! everywhere and an empty drops list, and select identically to v2.
//!
//! ## Cost semantics
//!
//! Conv/pcap candidates are priced by replaying the kernels' exact event
//! emissions from geometry alone (`kernels::conv::emit_*_conv_events`,
//! property-tested equal to the executed kernels' streams); capsule layers
//! are priced by executing the real routing kernel on zero operands. Conv
//! event counts are data-independent, so candidate *differences* (what the
//! argmin consumes) are exact; the data-dependent, strategy-invariant
//! parts (squash/softmax Newton iterations) cancel. Whole-network totals
//! are estimates (per-layer metering pays the cluster fork/join per layer,
//! pcap rows price the strategy-dependent conv only), which is why
//! [`Device::apply_plan`](crate::coordinator::Device::apply_plan)
//! re-measures the deployed latency end-to-end under the planned schedule.

mod memory;
mod planner;

pub use memory::{MemRegion, MemoryMap};
pub use planner::{plan_deployment, PlanOptions};

use crate::coordinator::BatchPolicy;
use crate::formats::JsonValue;
use crate::isa::{Board, Isa};
use crate::kernels::capsule::Nonlinearity;
use crate::kernels::conv::PulpConvStrategy;
use crate::model::{ArmConv, CapsNetConfig, PulpLayerExec, RiscvSchedule};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Schema version this build reads and writes (see module doc §Versioning).
pub const PLAN_VERSION: u32 = 3;

/// ISA family a plan was produced for, as serialized in the artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanIsa {
    ArmV7EM,
    ArmV8M,
    RiscvXpulp,
}

impl PlanIsa {
    pub fn from_isa(isa: Isa) -> PlanIsa {
        match isa {
            Isa::ArmV7EM => PlanIsa::ArmV7EM,
            Isa::ArmV8M => PlanIsa::ArmV8M,
            Isa::RiscvXpulp => PlanIsa::RiscvXpulp,
        }
    }

    pub fn is_arm(self) -> bool {
        matches!(self, PlanIsa::ArmV7EM | PlanIsa::ArmV8M)
    }

    pub fn as_str(self) -> &'static str {
        match self {
            PlanIsa::ArmV7EM => "arm-v7em",
            PlanIsa::ArmV8M => "arm-v8m",
            PlanIsa::RiscvXpulp => "riscv-xpulp",
        }
    }

    pub fn parse(s: &str) -> Result<PlanIsa> {
        Ok(match s {
            "arm-v7em" => PlanIsa::ArmV7EM,
            "arm-v8m" => PlanIsa::ArmV8M,
            "riscv-xpulp" => PlanIsa::RiscvXpulp,
            other => bail!("unknown plan isa '{other}'"),
        })
    }
}

/// Which stage of the network a [`LayerPlan`] describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Pcap,
    Caps,
}

impl LayerKind {
    pub fn as_str(self) -> &'static str {
        match self {
            LayerKind::Conv => "conv",
            LayerKind::Pcap => "pcap",
            LayerKind::Caps => "caps",
        }
    }

    pub fn parse(s: &str) -> Result<LayerKind> {
        Ok(match s {
            "conv" => LayerKind::Conv,
            "pcap" => LayerKind::Pcap,
            "caps" => LayerKind::Caps,
            other => bail!("unknown layer kind '{other}'"),
        })
    }
}

/// One kernel-strategy choice the planner can make for a layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyChoice {
    /// CMSIS-NN basic conv (always legal on Arm).
    ArmBasic,
    /// CMSIS-NN fast conv (requires `in_ch % 4 == 0 && out_ch % 2 == 0`).
    ArmFast,
    /// PULP conv, output channels split across cores.
    PulpCo,
    /// PULP conv, output rows split across cores.
    PulpHo,
    /// PULP conv, output pixels split across cores.
    PulpHoWo,
    /// Dynamic-routing capsule layer — no kernel alternatives, the choice
    /// is the core split only.
    Routing,
}

impl StrategyChoice {
    pub fn from_pulp(s: PulpConvStrategy) -> StrategyChoice {
        match s {
            PulpConvStrategy::Co => StrategyChoice::PulpCo,
            PulpConvStrategy::Ho => StrategyChoice::PulpHo,
            PulpConvStrategy::HoWo => StrategyChoice::PulpHoWo,
        }
    }

    /// The PULP strategy this choice resolves to, if it is one.
    pub fn as_pulp(self) -> Option<PulpConvStrategy> {
        match self {
            StrategyChoice::PulpCo => Some(PulpConvStrategy::Co),
            StrategyChoice::PulpHo => Some(PulpConvStrategy::Ho),
            StrategyChoice::PulpHoWo => Some(PulpConvStrategy::HoWo),
            _ => None,
        }
    }

    /// The Arm conv backend this choice resolves to, if it is one.
    /// `ArmFast` resolves to [`ArmConv::FastWithFallback`], which the
    /// kernels downgrade to basic on layers violating the fast-conv channel
    /// constraints — the planner only emits `ArmFast` where fast is legal,
    /// so the fallback never fires, but a corrupted plan degrades to a
    /// slower bit-identical kernel instead of a panic.
    pub fn as_arm(self) -> Option<ArmConv> {
        match self {
            StrategyChoice::ArmBasic => Some(ArmConv::Basic),
            StrategyChoice::ArmFast => Some(ArmConv::FastWithFallback),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            StrategyChoice::ArmBasic => "arm-basic",
            StrategyChoice::ArmFast => "arm-fast",
            StrategyChoice::PulpCo => "pulp-co",
            StrategyChoice::PulpHo => "pulp-ho",
            StrategyChoice::PulpHoWo => "pulp-howo",
            StrategyChoice::Routing => "routing",
        }
    }

    pub fn parse(s: &str) -> Result<StrategyChoice> {
        Ok(match s {
            "arm-basic" => StrategyChoice::ArmBasic,
            "arm-fast" => StrategyChoice::ArmFast,
            "pulp-co" => StrategyChoice::PulpCo,
            "pulp-ho" => StrategyChoice::PulpHo,
            "pulp-howo" => StrategyChoice::PulpHoWo,
            "routing" => StrategyChoice::Routing,
            other => bail!("unknown strategy '{other}'"),
        })
    }
}

/// One enumerated (strategy, core split, nonlinearity) candidate with its
/// metered cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CandidateCost {
    pub choice: StrategyChoice,
    pub cores: usize,
    /// Routing nonlinearity the candidate was priced with (always
    /// [`Nonlinearity::Exact`] for conv-stage layers).
    pub nonlin: Nonlinearity,
    pub cycles: u64,
}

/// The planner's decision for one layer, with the full candidate table kept
/// for auditability (`tools/plan_inspect.py` re-checks the argmin).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerPlan {
    pub name: String,
    pub kind: LayerKind,
    pub choice: StrategyChoice,
    pub cores: usize,
    /// Selected routing nonlinearity ([`Nonlinearity::Exact`] for every
    /// conv-stage layer; `Approx` only where the accuracy sweep admitted
    /// it and the argmin found it cheaper).
    pub nonlin: Nonlinearity,
    pub predicted_cycles: u64,
    pub candidates: Vec<CandidateCost>,
}

/// A complete, serializable deployment decision for (model, board):
/// per-layer kernel strategies, arena memory map, and batch policy.
#[derive(Clone, Debug, PartialEq)]
pub struct DeploymentPlan {
    pub plan_version: u32,
    /// `CapsNetConfig::name` this plan was derived from.
    pub model: String,
    /// `Board::name` whose cost model priced the candidates.
    pub board: String,
    pub isa: PlanIsa,
    /// Batch size the resident arena and staging slabs are sized for.
    pub batch_capacity: usize,
    /// Adaptive batching recommendation for this device's speed class.
    pub batch_window_ms: f64,
    pub batch_max: usize,
    pub layers: Vec<LayerPlan>,
    pub memory: MemoryMap,
    /// Sum of per-layer zero-activation estimates (see module doc §Cost).
    pub predicted_cycles: u64,
    pub predicted_ms: f64,
    /// Per-capsule-layer accuracy budget the approx candidates were
    /// admitted under (0 ⇒ the sweep was skipped and every layer is exact).
    pub accuracy_budget: f64,
    /// Calibration images the accuracy sweep classified per candidate
    /// (0 when the sweep was skipped).
    pub calibration_images: usize,
    /// Measured classification-agreement drop of the all-but-this-layer-
    /// exact approx candidate, one entry per capsule layer in layer order;
    /// empty when the sweep was skipped.
    pub caps_accuracy_drops: Vec<f64>,
}

fn parse_nonlin(s: &str) -> Result<Nonlinearity> {
    match Nonlinearity::parse(s) {
        Some(n) => Ok(n),
        None => bail!("unknown nonlinearity {s:?} (want \"exact\" or \"approx\")"),
    }
}

impl DeploymentPlan {
    /// The batching policy the plan recommends for this device.
    pub fn batch_policy(&self) -> BatchPolicy {
        BatchPolicy::new(self.batch_window_ms, self.batch_max.max(1))
    }

    /// Resolve the per-layer Arm conv schedule (`convs.len() + 1` entries:
    /// conv layers then the primary-capsule conv) for
    /// `forward_arm_scheduled_*`. Errors on RISC-V plans.
    pub fn arm_schedule(&self) -> Result<Vec<ArmConv>> {
        if !self.isa.is_arm() {
            bail!("plan for {} targets {}, not an Arm ISA", self.board, self.isa.as_str());
        }
        self.conv_stage_layers()
            .map(|l| {
                l.choice.as_arm().with_context(|| {
                    format!("layer {}: {} is not an Arm strategy", l.name, l.choice.as_str())
                })
            })
            .collect()
    }

    /// Resolve the per-layer RISC-V execution schedule (PULP strategy +
    /// cluster core split per conv-stage layer, core split per capsule
    /// layer) for `forward_riscv_scheduled_*`. Errors on Arm plans.
    pub fn riscv_schedule(&self) -> Result<RiscvSchedule> {
        if self.isa.is_arm() {
            bail!("plan for {} targets {}, not RISC-V", self.board, self.isa.as_str());
        }
        let conv = self
            .conv_stage_layers()
            .map(|l| {
                let strategy = l.choice.as_pulp().with_context(|| {
                    format!("layer {}: {} is not a PULP strategy", l.name, l.choice.as_str())
                })?;
                Ok(PulpLayerExec { strategy, cores: l.cores })
            })
            .collect::<Result<Vec<_>>>()?;
        let caps = self
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Caps)
            .map(|l| {
                if l.choice != StrategyChoice::Routing {
                    bail!(
                        "capsule layer {}: {} is not the routing kernel",
                        l.name,
                        l.choice.as_str()
                    );
                }
                Ok(l.cores)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RiscvSchedule { conv, caps })
    }

    /// The per-capsule-layer routing-nonlinearity selections, in layer
    /// order — what [`Program::lower_plan`](crate::exec::Program::lower_plan)
    /// threads into lowering. Errors if a conv-stage layer declares a
    /// non-exact nonlinearity (approximation applies to routing only).
    pub fn caps_nonlins(&self) -> Result<Vec<Nonlinearity>> {
        for l in self.conv_stage_layers() {
            if l.nonlin != Nonlinearity::Exact {
                bail!(
                    "layer {}: nonlinearity {} declared for a {} layer (only capsule \
                     routing layers may approximate)",
                    l.name,
                    l.nonlin.as_str(),
                    l.kind.as_str()
                );
            }
        }
        Ok(self
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Caps)
            .map(|l| l.nonlin)
            .collect())
    }

    /// The conv-stage layers a schedule covers, in execution order.
    fn conv_stage_layers(&self) -> impl Iterator<Item = &LayerPlan> {
        self.layers.iter().filter(|l| matches!(l.kind, LayerKind::Conv | LayerKind::Pcap))
    }

    /// Board-independent structural validation against a model
    /// architecture: version, model name, layer coverage (the schedule the
    /// forwards assert on), and sane batch fields. Consumers that execute
    /// a plan off-device ([`Fleet::serve_planned`](crate::coordinator::Fleet))
    /// use this so a truncated or hand-edited artifact is refused with an
    /// `Err` instead of panicking inside a worker thread.
    pub fn validate_model(&self, config: &CapsNetConfig) -> Result<()> {
        if self.plan_version != PLAN_VERSION {
            bail!("plan version {} != supported {PLAN_VERSION}", self.plan_version);
        }
        if self.model != config.name {
            bail!("plan is for model '{}', deployment runs '{}'", self.model, config.name);
        }
        let expected = config.conv_layers.len() + 1 + config.caps_layers.len();
        if self.layers.len() != expected {
            bail!("plan covers {} layers, model has {expected}", self.layers.len());
        }
        let conv_stage = self.conv_stage_layers().count();
        if conv_stage != config.conv_layers.len() + 1 {
            bail!(
                "plan has {conv_stage} conv-stage layers, model has {}",
                config.conv_layers.len() + 1
            );
        }
        if self.batch_capacity < 1 {
            bail!("plan batch_capacity must be >= 1");
        }
        if self.batch_max < 1 || self.batch_max > self.batch_capacity {
            bail!(
                "plan batch_policy.max_batch {} outside [1, batch_capacity={}]",
                self.batch_max,
                self.batch_capacity
            );
        }
        if self.batch_window_ms.is_nan() || self.batch_window_ms < 0.0 {
            bail!("plan batch_policy.window_ms must be a non-negative number");
        }
        if self.accuracy_budget.is_nan() || !(0.0..=1.0).contains(&self.accuracy_budget) {
            bail!("plan accuracy budget {} outside [0, 1]", self.accuracy_budget);
        }
        if !self.caps_accuracy_drops.is_empty()
            && self.caps_accuracy_drops.len() != config.caps_layers.len()
        {
            bail!(
                "plan records {} accuracy drops, model has {} capsule layers",
                self.caps_accuracy_drops.len(),
                config.caps_layers.len()
            );
        }
        for l in &self.layers {
            if l.nonlin != Nonlinearity::Exact && l.kind != LayerKind::Caps {
                bail!(
                    "layer {}: nonlinearity {} on a {} layer (approximation applies to \
                     capsule routing only)",
                    l.name,
                    l.nonlin.as_str(),
                    l.kind.as_str()
                );
            }
            if l.nonlin != Nonlinearity::Exact && self.accuracy_budget <= 0.0 {
                bail!(
                    "layer {}: approximate nonlinearity selected under a zero accuracy \
                     budget",
                    l.name
                );
            }
            if self.isa.is_arm() {
                // A core split on a single-core Arm board is a malformed
                // plan, not a degradable preference.
                if l.cores != 1 {
                    bail!(
                        "layer {}: core split {} declared for Arm plan (Arm boards are \
                         single-core)",
                        l.name,
                        l.cores
                    );
                }
            } else if !l.cores.is_power_of_two() {
                // cores == 0 is not a power of two, so this also rejects it.
                bail!(
                    "layer {}: core split {} is not a power of two (PULP-NN chunking \
                     requires 2^n cores)",
                    l.name,
                    l.cores
                );
            }
        }
        Ok(())
    }

    /// Validate that this plan matches a deployment target before applying
    /// it: the structural checks of [`Self::validate_model`] plus board
    /// identity, ISA, and per-layer core splits the board can actually run.
    pub fn validate_for(&self, config: &CapsNetConfig, board: &Board) -> Result<()> {
        self.validate_model(config)?;
        if self.board != board.name {
            bail!("plan is for board '{}', device is '{}'", self.board, board.name);
        }
        if self.isa != PlanIsa::from_isa(board.cost_model().isa) {
            bail!("plan isa {} does not match board {}", self.isa.as_str(), board.name);
        }
        for l in &self.layers {
            if l.cores > board.n_cores {
                bail!(
                    "layer {}: core split {} exceeds the {} cores of {}",
                    l.name,
                    l.cores,
                    board.n_cores,
                    board.name
                );
            }
        }
        Ok(())
    }

    // -- serialization -------------------------------------------------------

    pub fn to_json(&self) -> JsonValue {
        JsonValue::obj(vec![
            ("plan_version", JsonValue::int(self.plan_version as i64)),
            ("model", JsonValue::str(&self.model)),
            ("board", JsonValue::str(&self.board)),
            ("isa", JsonValue::str(self.isa.as_str())),
            ("batch_capacity", JsonValue::int(self.batch_capacity as i64)),
            (
                "batch_policy",
                JsonValue::obj(vec![
                    ("window_ms", JsonValue::num(self.batch_window_ms)),
                    ("max_batch", JsonValue::int(self.batch_max as i64)),
                ]),
            ),
            (
                "layers",
                JsonValue::Array(
                    self.layers
                        .iter()
                        .map(|l| {
                            JsonValue::obj(vec![
                                ("name", JsonValue::str(&l.name)),
                                ("kind", JsonValue::str(l.kind.as_str())),
                                ("strategy", JsonValue::str(l.choice.as_str())),
                                ("cores", JsonValue::int(l.cores as i64)),
                                ("nonlinearity", JsonValue::str(l.nonlin.as_str())),
                                ("predicted_cycles", JsonValue::int(l.predicted_cycles as i64)),
                                (
                                    "candidates",
                                    JsonValue::Array(
                                        l.candidates
                                            .iter()
                                            .map(|c| {
                                                JsonValue::obj(vec![
                                                    ("strategy", JsonValue::str(c.choice.as_str())),
                                                    ("cores", JsonValue::int(c.cores as i64)),
                                                    (
                                                        "nonlinearity",
                                                        JsonValue::str(c.nonlin.as_str()),
                                                    ),
                                                    ("cycles", JsonValue::int(c.cycles as i64)),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("memory", self.memory.to_json()),
            ("predicted_cycles", JsonValue::int(self.predicted_cycles as i64)),
            ("predicted_ms", JsonValue::num(self.predicted_ms)),
            (
                "accuracy",
                JsonValue::obj(vec![
                    ("budget", JsonValue::num(self.accuracy_budget)),
                    ("calibration_images", JsonValue::int(self.calibration_images as i64)),
                    (
                        "caps_layer_drops",
                        JsonValue::Array(
                            self.caps_accuracy_drops.iter().map(|&d| JsonValue::num(d)).collect(),
                        ),
                    ),
                ]),
            ),
        ])
    }

    pub fn from_json(v: &JsonValue) -> Result<DeploymentPlan> {
        // Compare in usize so out-of-range versions cannot truncate into a
        // supported one; the narrowing cast below is gated by the check.
        let version = v.req("plan_version")?.as_usize()?;
        if version != PLAN_VERSION as usize {
            bail!(
                "unsupported plan_version {version} (this build reads version {PLAN_VERSION}; \
                 regenerate the plan with `capsnet-edge plan`)"
            );
        }
        let version = version as u32;
        let policy = v.req("batch_policy")?;
        let layers = v
            .req("layers")?
            .as_array()?
            .iter()
            .map(|l| {
                let candidates = l
                    .req("candidates")?
                    .as_array()?
                    .iter()
                    .map(|c| {
                        Ok(CandidateCost {
                            choice: StrategyChoice::parse(c.req("strategy")?.as_str()?)?,
                            cores: c.req("cores")?.as_usize()?,
                            nonlin: parse_nonlin(c.req("nonlinearity")?.as_str()?)?,
                            // as_usize rejects negatives — a corrupted
                            // "cycles": -1 must not wrap to u64::MAX.
                            cycles: c.req("cycles")?.as_usize()? as u64,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(LayerPlan {
                    name: l.req("name")?.as_str()?.to_string(),
                    kind: LayerKind::parse(l.req("kind")?.as_str()?)?,
                    choice: StrategyChoice::parse(l.req("strategy")?.as_str()?)?,
                    cores: l.req("cores")?.as_usize()?,
                    nonlin: parse_nonlin(l.req("nonlinearity")?.as_str()?)?,
                    predicted_cycles: l.req("predicted_cycles")?.as_usize()? as u64,
                    candidates,
                })
            })
            .collect::<Result<Vec<_>>>()
            .context("layers")?;
        let accuracy = v.req("accuracy").context("accuracy")?;
        let caps_accuracy_drops = accuracy
            .req("caps_layer_drops")?
            .as_array()?
            .iter()
            .map(|d| d.as_f64())
            .collect::<Result<Vec<_>>>()
            .context("accuracy.caps_layer_drops")?;
        Ok(DeploymentPlan {
            plan_version: version,
            model: v.req("model")?.as_str()?.to_string(),
            board: v.req("board")?.as_str()?.to_string(),
            isa: PlanIsa::parse(v.req("isa")?.as_str()?)?,
            batch_capacity: v.req("batch_capacity")?.as_usize()?,
            batch_window_ms: policy.req("window_ms")?.as_f64()?,
            batch_max: policy.req("max_batch")?.as_usize()?,
            layers,
            memory: MemoryMap::from_json(v.req("memory")?).context("memory")?,
            predicted_cycles: v.req("predicted_cycles")?.as_usize()? as u64,
            predicted_ms: v.req("predicted_ms")?.as_f64()?,
            accuracy_budget: accuracy.req("budget")?.as_f64()?,
            calibration_images: accuracy.req("calibration_images")?.as_usize()?,
            caps_accuracy_drops,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_string_pretty())
            .with_context(|| format!("writing plan to {}", path.as_ref().display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<DeploymentPlan> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading plan from {}", path.as_ref().display()))?;
        Self::from_json(&JsonValue::parse(&text)?)
    }

    /// Human-readable rendering for the `plan` CLI subcommand.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "── deployment plan v{} — {} on {} ({}) ──",
            self.plan_version,
            self.model,
            self.board,
            self.isa.as_str()
        );
        let _ = writeln!(
            out,
            "predicted: {:.2}M cycles/inference ≈ {:.2} ms | batch capacity {} | \
             batch policy: up to {} per {:.1} ms window",
            self.predicted_cycles as f64 / 1e6,
            self.predicted_ms,
            self.batch_capacity,
            self.batch_max,
            self.batch_window_ms
        );
        if self.accuracy_budget > 0.0 {
            let drops: Vec<String> =
                self.caps_accuracy_drops.iter().map(|d| format!("{d:.3}")).collect();
            let _ = writeln!(
                out,
                "accuracy budget {:.3} over {} calibration images | measured caps drops: [{}]",
                self.accuracy_budget,
                self.calibration_images,
                drops.join(", ")
            );
        }
        let _ = writeln!(
            out,
            "\nlayer        kind   strategy    cores  nonlin      cycles   candidates"
        );
        for l in &self.layers {
            let cands: Vec<String> = l
                .candidates
                .iter()
                .map(|c| {
                    let nl = if c.nonlin == Nonlinearity::Approx { "~approx" } else { "" };
                    format!("{}x{}{}:{:.2}M", c.choice.as_str(), c.cores, nl, c.cycles as f64 / 1e6)
                })
                .collect();
            let _ = writeln!(
                out,
                "{:<12} {:<6} {:<11} {:>5}  {:<6} {:>11} | {}",
                l.name,
                l.kind.as_str(),
                l.choice.as_str(),
                l.cores,
                l.nonlin.as_str(),
                l.predicted_cycles,
                cands.join(" ")
            );
        }
        out.push('\n');
        out.push_str(&self.memory.render());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::configs;

    fn plans() -> Vec<DeploymentPlan> {
        let mut out = Vec::new();
        for cfg in configs::all() {
            for board in [Board::stm32h755(), Board::gapuino()] {
                out.push(plan_deployment(&cfg, &board, &PlanOptions::default()));
            }
        }
        out
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        for plan in plans() {
            let text = plan.to_json().to_string_pretty();
            let back = DeploymentPlan::from_json(&JsonValue::parse(&text).unwrap()).unwrap();
            assert_eq!(back, plan, "{} on {}", plan.model, plan.board);
            // compact form round-trips too
            let compact = plan.to_json().to_string_compact();
            let back2 = DeploymentPlan::from_json(&JsonValue::parse(&compact).unwrap()).unwrap();
            assert_eq!(back2, plan);
        }
    }

    #[test]
    fn unknown_version_is_rejected_with_guidance() {
        let plan = plan_deployment(&configs::mnist(), &Board::gapuino(), &PlanOptions::default());
        let mut j = plan.to_json();
        if let JsonValue::Object(fields) = &mut j {
            fields[0].1 = JsonValue::int(99);
        }
        let err = DeploymentPlan::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("plan_version 99"), "{err}");
        assert!(err.contains("capsnet-edge plan"), "{err}");
    }

    #[test]
    fn schedules_resolve_per_isa_only() {
        let cfg = configs::cifar10();
        let arm = plan_deployment(&cfg, &Board::stm32h755(), &PlanOptions::default());
        let rv = plan_deployment(&cfg, &Board::gapuino(), &PlanOptions::default());
        let n = cfg.conv_layers.len() + 1;
        assert_eq!(arm.arm_schedule().unwrap().len(), n);
        let sched = rv.riscv_schedule().unwrap();
        assert_eq!(sched.conv.len(), n);
        assert_eq!(sched.caps.len(), cfg.caps_layers.len());
        assert!(sched.splits().all(|c| c.is_power_of_two() && c <= 8));
        assert!(arm.riscv_schedule().is_err());
        assert!(rv.arm_schedule().is_err());
    }

    #[test]
    fn malformed_core_splits_are_refused() {
        let cfg = configs::cifar10();
        let board = Board::gapuino();
        let base = plan_deployment(&cfg, &board, &PlanOptions::default());
        assert!(base.validate_for(&cfg, &board).is_ok());
        // split larger than the board's cluster
        let mut plan = base.clone();
        plan.layers[0].cores = 16;
        assert!(plan.validate_for(&cfg, &board).is_err(), "16-core split on 8-core board");
        // non-power-of-two split (structural — caught board-independently)
        let mut plan = base.clone();
        plan.layers[1].cores = 3;
        assert!(plan.validate_model(&cfg).is_err(), "3-core split accepted");
        // zero split
        let mut plan = base.clone();
        plan.layers[0].cores = 0;
        assert!(plan.validate_model(&cfg).is_err(), "0-core split accepted");
        // any split on an Arm plan
        let arm = plan_deployment(&cfg, &Board::stm32h755(), &PlanOptions::default());
        let mut plan = arm.clone();
        plan.layers[0].cores = 2;
        assert!(plan.validate_model(&cfg).is_err(), "core split on Arm plan accepted");
    }

    #[test]
    fn validate_for_rejects_mismatches() {
        let cfg = configs::mnist();
        let plan = plan_deployment(&cfg, &Board::gapuino(), &PlanOptions::default());
        assert!(plan.validate_for(&cfg, &Board::gapuino()).is_ok());
        assert!(plan.validate_for(&configs::cifar10(), &Board::gapuino()).is_err());
        assert!(plan.validate_for(&cfg, &Board::stm32h755()).is_err());
    }

    #[test]
    fn structurally_damaged_plans_are_refused() {
        // A truncated or hand-edited artifact must fail the board-independent
        // structural check (what serve_planned runs) instead of panicking
        // later inside an executing thread.
        let cfg = configs::cifar10();
        let mut plan = plan_deployment(&cfg, &Board::stm32h755(), &PlanOptions::default());
        assert!(plan.validate_model(&cfg).is_ok());
        let dropped = plan.layers.pop().unwrap();
        assert!(plan.validate_model(&cfg).is_err(), "truncated layer list accepted");
        plan.layers.push(dropped);
        plan.batch_window_ms = -1.0;
        assert!(plan.validate_model(&cfg).is_err(), "negative batch window accepted");
        plan.batch_window_ms = 0.0;
        plan.batch_max = 0;
        assert!(plan.validate_model(&cfg).is_err(), "zero max_batch accepted");
    }

    #[test]
    fn render_mentions_every_layer_and_the_arena() {
        let plan = plan_deployment(&configs::mnist(), &Board::gapuino(), &PlanOptions::default());
        let r = plan.render();
        for l in &plan.layers {
            assert!(r.contains(&l.name), "render missing {}", l.name);
        }
        assert!(r.contains("arena"), "render missing memory map:\n{r}");
    }

    #[test]
    fn strategy_and_kind_strings_roundtrip() {
        for c in [
            StrategyChoice::ArmBasic,
            StrategyChoice::ArmFast,
            StrategyChoice::PulpCo,
            StrategyChoice::PulpHo,
            StrategyChoice::PulpHoWo,
            StrategyChoice::Routing,
        ] {
            assert_eq!(StrategyChoice::parse(c.as_str()).unwrap(), c);
        }
        for k in [LayerKind::Conv, LayerKind::Pcap, LayerKind::Caps] {
            assert_eq!(LayerKind::parse(k.as_str()).unwrap(), k);
        }
        for i in [PlanIsa::ArmV7EM, PlanIsa::ArmV8M, PlanIsa::RiscvXpulp] {
            assert_eq!(PlanIsa::parse(i.as_str()).unwrap(), i);
        }
        assert!(StrategyChoice::parse("warp-drive").is_err());
    }
}
