//! Deployment memory map: the exact batched-arena layout a plan deploys.
//!
//! The zero-alloc forward paths carve three regions from one resident
//! [`Workspace`](crate::kernels::workspace::Workspace), in a fixed order
//! (see `QuantizedCapsNet::forward_*_batched_into`): the ping activation
//! slab, the pong activation slab, then the largest batched kernel scratch.
//! This module derives that layout — offsets included — from the same
//! `scratch_len_batched` contract the carver uses, so the map is exact by
//! construction, and pairs it with the paper-§5 deployment footprint
//! (int-8 model + peak activations vs. 80 % of board RAM).

use crate::formats::JsonValue;
use crate::isa::Board;
use crate::model::CapsNetConfig;
use anyhow::{Context, Result};

/// One carve-out of the resident arena (offsets in bytes from arena start).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MemRegion {
    pub name: String,
    pub offset: usize,
    pub bytes: usize,
}

/// The full memory story of a deployment: arena regions (carver order),
/// staging slabs, and the admission-rule footprint.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MemoryMap {
    /// Total resident arena (`CapsNetConfig::scratch_i8_len_batched`).
    pub arena_bytes: usize,
    /// Carve-outs within the arena, contiguous from offset 0.
    pub regions: Vec<MemRegion>,
    /// Resident batched input staging slab (`batch × input_len`).
    pub staging_in_bytes: usize,
    /// Resident batched output staging slab (`batch × output_len`).
    pub staging_out_bytes: usize,
    /// Int-8 model footprint incl. shift parameters (paper Table 2).
    pub model_bytes: usize,
    /// Model + peak overlapped activations (the MCU admission quantity).
    pub deployed_bytes: usize,
    /// 80 % of the board's RAM (paper §5 deployment rule).
    pub usable_ram_bytes: usize,
    /// `deployed_bytes <= usable_ram_bytes`.
    pub fits: bool,
}

impl MemoryMap {
    /// The arena carve-outs for `config` at `batch_capacity`, in carver
    /// order — the single source both this map and the execution engine's
    /// [`Program::arena_layout`](crate::exec::Program::arena_layout) derive
    /// from (program lowering reads these regions verbatim; a property test
    /// in `tests/exec_engine.rs` pins the agreement).
    pub fn arena_regions(config: &CapsNetConfig, batch_capacity: usize) -> Vec<MemRegion> {
        let n = batch_capacity.max(1);
        let act = n * config.max_activation_len();
        let kscratch = config.max_kernel_scratch_len_batched(n);
        vec![
            MemRegion { name: "act_ping".into(), offset: 0, bytes: act },
            MemRegion { name: "act_pong".into(), offset: act, bytes: act },
            MemRegion { name: "kernel_scratch".into(), offset: 2 * act, bytes: kscratch },
        ]
    }

    /// Derive the map for `config` deployed on `board` with a resident
    /// arena sized for batches of up to `batch_capacity` images.
    pub fn for_deployment(config: &CapsNetConfig, board: &Board, batch_capacity: usize) -> Self {
        let n = batch_capacity.max(1);
        let regions = Self::arena_regions(config, n);
        let deployed = config.deployed_bytes();
        let usable = board.usable_ram_bytes();
        MemoryMap {
            arena_bytes: config.scratch_i8_len_batched(n),
            regions,
            staging_in_bytes: n * config.input_len(),
            staging_out_bytes: n * config.output_len(),
            model_bytes: config.int8_bytes(),
            deployed_bytes: deployed,
            usable_ram_bytes: usable,
            fits: deployed <= usable,
        }
    }

    pub fn to_json(&self) -> JsonValue {
        JsonValue::obj(vec![
            ("arena_bytes", JsonValue::int(self.arena_bytes as i64)),
            (
                "regions",
                JsonValue::Array(
                    self.regions
                        .iter()
                        .map(|r| {
                            JsonValue::obj(vec![
                                ("name", JsonValue::str(&r.name)),
                                ("offset", JsonValue::int(r.offset as i64)),
                                ("bytes", JsonValue::int(r.bytes as i64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("staging_in_bytes", JsonValue::int(self.staging_in_bytes as i64)),
            ("staging_out_bytes", JsonValue::int(self.staging_out_bytes as i64)),
            ("model_bytes", JsonValue::int(self.model_bytes as i64)),
            ("deployed_bytes", JsonValue::int(self.deployed_bytes as i64)),
            ("usable_ram_bytes", JsonValue::int(self.usable_ram_bytes as i64)),
            ("fits", JsonValue::Bool(self.fits)),
        ])
    }

    pub fn from_json(v: &JsonValue) -> Result<MemoryMap> {
        let regions = v
            .req("regions")?
            .as_array()?
            .iter()
            .map(|r| {
                Ok(MemRegion {
                    name: r.req("name")?.as_str()?.to_string(),
                    offset: r.req("offset")?.as_usize()?,
                    bytes: r.req("bytes")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()
            .context("regions")?;
        Ok(MemoryMap {
            arena_bytes: v.req("arena_bytes")?.as_usize()?,
            regions,
            staging_in_bytes: v.req("staging_in_bytes")?.as_usize()?,
            staging_out_bytes: v.req("staging_out_bytes")?.as_usize()?,
            model_bytes: v.req("model_bytes")?.as_usize()?,
            deployed_bytes: v.req("deployed_bytes")?.as_usize()?,
            usable_ram_bytes: v.req("usable_ram_bytes")?.as_usize()?,
            fits: v.req("fits")?.as_bool()?,
        })
    }

    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let kb = |b: usize| b as f64 / 1024.0;
        let mut out = String::new();
        let _ = writeln!(out, "memory map (host arena {:.1} KB):", kb(self.arena_bytes));
        for r in &self.regions {
            let _ = writeln!(
                out,
                "  {:>8} +{:<8} {:<15} {:.1} KB",
                r.offset,
                r.bytes,
                r.name,
                kb(r.bytes)
            );
        }
        let _ = writeln!(
            out,
            "  staging: in {:.1} KB, out {:.1} KB",
            kb(self.staging_in_bytes),
            kb(self.staging_out_bytes)
        );
        let _ = writeln!(
            out,
            "MCU deployment: model {:.1} KB, deployed {:.1} KB of {:.1} KB usable — {}",
            kb(self.model_bytes),
            kb(self.deployed_bytes),
            kb(self.usable_ram_bytes),
            if self.fits { "fits" } else { "DOES NOT FIT" }
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Device;
    use crate::model::{configs, QuantizedCapsNet};
    use std::sync::Arc;

    #[test]
    fn regions_are_contiguous_and_sum_to_the_arena() {
        for cfg in configs::all() {
            for n in [1usize, 4, 8] {
                let map = MemoryMap::for_deployment(&cfg, &Board::gapuino(), n);
                let mut cursor = 0usize;
                for r in &map.regions {
                    assert_eq!(r.offset, cursor, "{}: region {} offset", cfg.name, r.name);
                    cursor += r.bytes;
                }
                assert_eq!(cursor, map.arena_bytes, "{}: batch {n}", cfg.name);
                assert_eq!(map.arena_bytes, cfg.scratch_i8_len_batched(n));
                assert_eq!(map.staging_in_bytes, n * cfg.input_len());
                assert_eq!(map.staging_out_bytes, n * cfg.output_len());
            }
        }
    }

    #[test]
    fn fits_flag_agrees_with_device_admission() {
        // The map's fits flag is the same predicate Device::deploy enforces.
        for cfg in configs::all() {
            for board in Board::all() {
                let map = MemoryMap::for_deployment(&cfg, &board, 8);
                let model = Arc::new(QuantizedCapsNet::random(cfg.clone(), 1));
                let admitted = Device::deploy(0, board.clone(), model).is_ok();
                assert_eq!(map.fits, admitted, "{} on {}", cfg.name, board.name);
            }
        }
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let cfg = configs::mnist();
        let a = MemoryMap::for_deployment(&cfg, &Board::gapuino(), 0);
        let b = MemoryMap::for_deployment(&cfg, &Board::gapuino(), 1);
        assert_eq!(a, b);
    }
}
