//! # capsnet-edge
//!
//! Reproduction of *"Shifting Capsule Networks from the Cloud to the Deep
//! Edge"* (Costa et al., 2021): int-8 quantized Capsule Network inference
//! kernels for Arm Cortex-M and RISC-V RV32IMCXpulp MCUs, a post-training
//! quantization framework, and an edge-fleet serving coordinator.
//!
//! The crate is the Layer-3 (Rust) half of a three-layer stack:
//!
//! * **L1/L2 (build time, Python)** — JAX + Pallas author the CapsNet float
//!   model and the quantized-arithmetic simulation graph; both are AOT-lowered
//!   to HLO text under `artifacts/` and the trained + quantized models are
//!   exported as `.cnq` binaries.
//! * **L3 (this crate)** — loads the artifacts and serves inference over a
//!   fleet of *simulated* MCUs. The q7 kernels in [`kernels`] are bit-exact
//!   functional models of the paper's CMSIS-NN / PULP-NN extensions,
//!   instrumented with the instruction-event cycle models in [`isa`], so the
//!   paper's latency tables (3–8) are regenerated from first principles.
//!
//! See `examples/quickstart.rs` for an end-to-end walkthrough and DESIGN.md
//! for the full system inventory.

// The instrumented kernels mirror C kernel signatures (operands, dims,
// shifts, placement, scratch, meter) — argument-count lints fight that
// deliberately C-shaped API.
#![allow(clippy::too_many_arguments)]

pub mod exec;
pub mod fixedpoint;
pub mod formats;
pub mod isa;
pub mod kernels;
pub mod quant;
pub mod model;
pub mod dataset;
pub mod runtime;
pub mod coordinator;
pub mod obs;
pub mod plan;
pub mod bench_support;
pub mod testing;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
