//! PJRT runtime — loads AOT-compiled HLO artifacts and executes them.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT): HLO **text**
//! (written by `python/compile/aot.py`) → `HloModuleProto::from_text_file`
//! → `PjRtClient::compile` → `execute`. Text is the interchange format
//! because jax ≥ 0.5 emits protos with 64-bit instruction ids that this
//! XLA rejects (see aot.py and /opt/xla-example/README.md).
//!
//! The runtime backs the *float reference* path (cross-checking the native
//! Rust engines against the exact JAX graph) and the `qsim` arithmetic
//! cross-check. The int-8 serving hot path never goes through here — it
//! runs the native kernels in [`crate::kernels`].

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled HLO executable plus its metadata.
pub struct LoadedModule {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModule {
    /// Execute with f32 inputs, returning the flattened f32 outputs of the
    /// result tuple (aot.py lowers with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let literals = inputs
            .iter()
            .map(|(data, dims)| {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .context("reshaping input literal")
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        tuple
            .into_iter()
            .map(|l| l.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }

    /// Execute with i8 inputs → i8 outputs (the qsim cross-check path).
    ///
    /// `i8` has no `NativeType` constructor in xla 0.1.6, so the literal is
    /// built from untyped bytes with an explicit `S8` element type.
    pub fn run_i8(&self, inputs: &[(&[i8], &[usize])]) -> Result<Vec<Vec<i8>>> {
        let literals = inputs
            .iter()
            .map(|(data, dims)| {
                let bytes: &[u8] =
                    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S8,
                    dims,
                    bytes,
                )
                .context("building i8 input literal")
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        tuple
            .into_iter()
            .map(|l| l.to_vec::<i8>().context("reading i8 output"))
            .collect()
    }
}

/// Registry of compiled artifacts, keyed by file stem.
pub struct Runtime {
    client: xla::PjRtClient,
    modules: HashMap<String, LoadedModule>,
}

impl Runtime {
    /// CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, modules: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO text file; registers it under its file stem
    /// (e.g. `mnist_float`).
    pub fn load_hlo(&mut self, path: impl AsRef<Path>) -> Result<&LoadedModule> {
        let path = path.as_ref();
        let name = path
            .file_name()
            .and_then(|s| s.to_str())
            .map(|s| s.trim_end_matches(".hlo.txt").to_string())
            .unwrap_or_default();
        if name.is_empty() {
            bail!("cannot derive module name from {}", path.display());
        }
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        self.modules.insert(name.clone(), LoadedModule { name: name.clone(), exe });
        Ok(&self.modules[&name])
    }

    /// Load every `*.hlo.txt` under a directory (sorted for determinism).
    pub fn load_dir(&mut self, dir: impl AsRef<Path>) -> Result<Vec<String>> {
        let mut loaded = Vec::new();
        let dir = dir.as_ref();
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("reading artifact dir {}", dir.display()))?;
        let mut paths: Vec<PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.to_string_lossy().ends_with(".hlo.txt"))
            .collect();
        paths.sort();
        for p in paths {
            let m = self.load_hlo(&p)?;
            loaded.push(m.name.clone());
        }
        Ok(loaded)
    }

    pub fn get(&self, name: &str) -> Option<&LoadedModule> {
        self.modules.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.modules.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }
}

/// Artifact root: `$CAPSNET_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("CAPSNET_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
