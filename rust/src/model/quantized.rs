//! Quantized CapsNet model + forward-pass entry points.
//!
//! Loads a `.cnq` archive produced by `python/compile/quantize.py`
//! (Algorithm 6) and runs int-8 inference through the compile-once
//! execution engine ([`crate::exec`]): every `forward_*` method lowers its
//! schedule into a [`Program`](crate::exec::Program) and interprets it on
//! the matching [`KernelBackend`](crate::exec::KernelBackend). The
//! arithmetic is bit-identical to the Python int-simulation graph —
//! verified by the exported test vectors.

use crate::exec::{run_program, run_program_batched, ArmBackend, Program, PulpBackend};
use crate::formats::{Archive, JsonValue, Tensor};
use crate::isa::{ClusterRun, Meter};
use crate::kernels::capsule::CapsuleShifts;
use crate::kernels::conv::PulpConvStrategy;
use crate::kernels::pcap::PcapShifts;
use crate::kernels::squash::SquashParams;
use crate::kernels::workspace::Workspace;
use crate::model::config::CapsNetConfig;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A quantized convolutional layer.
#[derive(Clone, Debug)]
pub struct QConvLayer {
    pub w: Vec<i8>,
    pub b: Vec<i8>,
    pub bias_shift: u32,
    pub out_shift: u32,
}

/// The quantized primary capsule layer.
#[derive(Clone, Debug)]
pub struct QPcapLayer {
    pub w: Vec<i8>,
    pub b: Vec<i8>,
    pub shifts: PcapShifts,
}

/// A quantized capsule layer.
#[derive(Clone, Debug)]
pub struct QCapsLayer {
    pub w: Vec<i8>,
    pub shifts: CapsuleShifts,
}

/// A fully quantized CapsNet, ready for int-8 inference.
#[derive(Clone, Debug)]
pub struct QuantizedCapsNet {
    pub config: CapsNetConfig,
    /// Fractional bits of the quantized input (images are scaled by
    /// `2^input_qn` and clipped to `[-128, 127]`).
    pub input_qn: i32,
    pub convs: Vec<QConvLayer>,
    pub pcap: QPcapLayer,
    pub caps: Vec<QCapsLayer>,
}

/// Conv backend selection for Arm forward passes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArmConv {
    Basic,
    /// Fast conv where the layer satisfies the channel constraints,
    /// falling back to basic otherwise.
    FastWithFallback,
}

/// One conv-stage layer's RISC-V execution directive: which PULP
/// parallelization strategy the layer runs and on how many cluster cores.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PulpLayerExec {
    pub strategy: PulpConvStrategy,
    /// Power-of-two cluster core split (clamped to the executing cluster;
    /// every split computes the same function, only the meter differs).
    pub cores: usize,
}

/// Per-layer RISC-V execution schedule — what a GAP-8
/// [`DeploymentPlan`](crate::plan::DeploymentPlan) resolves to. Unlike the
/// Arm schedule (a conv-backend list), every RISC-V layer also carries its
/// own cluster core split, so a plan that runs a tiny tail layer on fewer
/// cores (skipping the fork/join it cannot amortize) is honored by the
/// executing kernels and priced identically by the event meter.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RiscvSchedule {
    /// Conv layers then the primary-capsule convolution, execution order
    /// (`convs.len() + 1` entries).
    pub conv: Vec<PulpLayerExec>,
    /// Core split per capsule layer (dynamic routing has no kernel
    /// alternatives — the split is the whole decision).
    pub caps: Vec<usize>,
}

impl RiscvSchedule {
    /// Uniform schedule: one strategy and one core split for every layer —
    /// the pinned default expressed as a schedule.
    pub fn uniform(
        strategy: PulpConvStrategy,
        cores: usize,
        n_convs: usize,
        n_caps: usize,
    ) -> Self {
        RiscvSchedule {
            conv: vec![PulpLayerExec { strategy, cores }; n_convs + 1],
            caps: vec![cores; n_caps],
        }
    }

    /// Core splits in layer execution order (conv stage then capsule
    /// layers) — the order `ClusterRun::sections` records.
    pub fn splits(&self) -> impl Iterator<Item = usize> + '_ {
        self.conv.iter().map(|l| l.cores).chain(self.caps.iter().copied())
    }
}

impl QuantizedCapsNet {
    // -- loading -------------------------------------------------------------

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let archive = Archive::load(path)?;
        Self::from_archive(&archive)
    }

    pub fn from_archive(a: &Archive) -> Result<Self> {
        let cfg_bytes = a.req("config.json")?.as_u8()?;
        let cfg_text = std::str::from_utf8(cfg_bytes).context("config.json utf8")?;
        let config = CapsNetConfig::from_json(&JsonValue::parse(cfg_text)?)?;

        let scalar = |name: &str| -> Result<i32> { a.req(name)?.scalar_i32() };
        let shift = |name: &str| -> Result<u32> {
            let v = scalar(name)?;
            u32::try_from(v).with_context(|| format!("{name} must be non-negative, got {v}"))
        };
        let ivec = |name: &str| -> Result<Vec<i32>> { Ok(a.req(name)?.as_i32()?.to_vec()) };
        let uvec = |name: &str| -> Result<Vec<u32>> {
            ivec(name)?
                .into_iter()
                .map(|v| u32::try_from(v).with_context(|| format!("{name}: negative shift {v}")))
                .collect()
        };

        let input_qn = scalar("input_qn")?;

        let mut convs = Vec::new();
        for i in 0..config.conv_layers.len() {
            let d = config.conv_dims(i);
            let w = a.req(&format!("conv{i}.w"))?.as_i8()?.to_vec();
            let b = a.req(&format!("conv{i}.b"))?.as_i8()?.to_vec();
            if w.len() != d.weight_len() || b.len() != d.out_ch {
                bail!(
                    "conv{i}: weight/bias sizes {}x{} do not match config {}x{}",
                    w.len(), b.len(), d.weight_len(), d.out_ch
                );
            }
            convs.push(QConvLayer {
                w,
                b,
                bias_shift: shift(&format!("conv{i}.bias_shift"))?,
                out_shift: shift(&format!("conv{i}.out_shift"))?,
            });
        }

        let pd = config.pcap_dims();
        let pw = a.req("pcap.w")?.as_i8()?.to_vec();
        let pb = a.req("pcap.b")?.as_i8()?.to_vec();
        if pw.len() != pd.conv.weight_len() || pb.len() != pd.conv.out_ch {
            bail!("pcap weight/bias sizes do not match config");
        }
        let pcap = QPcapLayer {
            w: pw,
            b: pb,
            shifts: PcapShifts {
                bias_shift: shift("pcap.bias_shift")?,
                out_shift: shift("pcap.out_shift")?,
                squash: SquashParams::q7_out(scalar("pcap.squash_in_qn")?),
            },
        };

        let mut caps = Vec::new();
        for i in 0..config.caps_layers.len() {
            let d = config.caps_dims(i);
            let w = a.req(&format!("caps{i}.w"))?.as_i8()?.to_vec();
            if w.len() != d.weight_len() {
                // The size check doubles as the packing validation: archives
                // store weights pre-packed in the `[out_caps][in_caps]
                // [out_dim][in_dim]` block order the batched prediction-vector
                // GEMM walks (see `PackedCapsWeights`), so load is the only
                // place layout can go wrong.
                bail!("caps{i}: weight size {} != config {}", w.len(), d.weight_len());
            }
            let shifts = CapsuleShifts {
                inputs_hat: shift(&format!("caps{i}.inputs_hat_shift"))?,
                caps_out: uvec(&format!("caps{i}.caps_out_shifts"))?,
                squash_in_qn: ivec(&format!("caps{i}.squash_in_qns"))?,
                agreement: uvec(&format!("caps{i}.agreement_shifts"))?,
                logit_acc: uvec(&format!("caps{i}.logit_acc_shifts"))?,
            };
            shifts.validate(config.caps_layers[i].routings);
            caps.push(QCapsLayer { w, shifts });
        }

        Ok(QuantizedCapsNet { config, input_qn, convs, pcap, caps })
    }

    /// Serialize back to an archive (inverse of [`Self::from_archive`]).
    pub fn to_archive(&self) -> Archive {
        let mut a = Archive::new();
        let cfg = self.config.to_json().to_string_compact();
        a.insert("config.json", Tensor::U8 { dims: vec![cfg.len()], data: cfg.into_bytes() });
        let s = |v: i32| Tensor::I32 { dims: vec![1], data: vec![v] };
        let sv = |v: &[u32]| Tensor::I32 {
            dims: vec![v.len()],
            data: v.iter().map(|&x| x as i32).collect(),
        };
        a.insert("input_qn", s(self.input_qn));
        for (i, c) in self.convs.iter().enumerate() {
            a.insert(&format!("conv{i}.w"), Tensor::I8 { dims: vec![c.w.len()], data: c.w.clone() });
            a.insert(&format!("conv{i}.b"), Tensor::I8 { dims: vec![c.b.len()], data: c.b.clone() });
            a.insert(&format!("conv{i}.bias_shift"), s(c.bias_shift as i32));
            a.insert(&format!("conv{i}.out_shift"), s(c.out_shift as i32));
        }
        a.insert("pcap.w", Tensor::I8 { dims: vec![self.pcap.w.len()], data: self.pcap.w.clone() });
        a.insert("pcap.b", Tensor::I8 { dims: vec![self.pcap.b.len()], data: self.pcap.b.clone() });
        a.insert("pcap.bias_shift", s(self.pcap.shifts.bias_shift as i32));
        a.insert("pcap.out_shift", s(self.pcap.shifts.out_shift as i32));
        a.insert("pcap.squash_in_qn", s(self.pcap.shifts.squash.in_qn));
        for (i, c) in self.caps.iter().enumerate() {
            a.insert(&format!("caps{i}.w"), Tensor::I8 { dims: vec![c.w.len()], data: c.w.clone() });
            a.insert(&format!("caps{i}.inputs_hat_shift"), s(c.shifts.inputs_hat as i32));
            a.insert(&format!("caps{i}.caps_out_shifts"), sv(&c.shifts.caps_out));
            a.insert(
                &format!("caps{i}.squash_in_qns"),
                Tensor::I32 { dims: vec![c.shifts.squash_in_qn.len()], data: c.shifts.squash_in_qn.clone() },
            );
            a.insert(&format!("caps{i}.agreement_shifts"), sv(&c.shifts.agreement));
            a.insert(&format!("caps{i}.logit_acc_shifts"), sv(&c.shifts.logit_acc));
        }
        a
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        self.to_archive().save(path)
    }

    // -- inference -----------------------------------------------------------

    /// Quantize a float image into the network's input format.
    pub fn quantize_input(&self, img: &[f32]) -> Vec<i8> {
        let mut out = vec![0i8; img.len()];
        self.quantize_input_into(img, &mut out);
        out
    }

    /// Allocation-free [`Self::quantize_input`] into a caller buffer —
    /// calibration sweeps quantize thousands of images into one resident
    /// staging buffer (see [`crate::quant::Calibrator`]).
    pub fn quantize_input_into(&self, img: &[f32], out: &mut [i8]) {
        assert_eq!(img.len(), out.len(), "quantize_input size");
        let scale = 2f64.powi(self.input_qn);
        for (dst, &x) in out.iter_mut().zip(img.iter()) {
            *dst = (x as f64 * scale).round().clamp(-128.0, 127.0) as i8;
        }
    }

    /// Arm Cortex-M forward pass. Returns the final capsule outputs
    /// `[num_classes × cap_dim]` (q7).
    ///
    /// Allocating convenience wrapper over [`Self::forward_arm_into`] —
    /// builds a one-shot workspace per call. Serving paths hold a
    /// [`Workspace`] and call the `_into` variant instead.
    pub fn forward_arm<M: Meter>(&self, input_q: &[i8], conv: ArmConv, m: &mut M) -> Vec<i8> {
        let mut ws = self.config.workspace();
        let mut out = vec![0i8; self.config.output_len()];
        self.forward_arm_into(input_q, conv, &mut ws, &mut out, m);
        out
    }

    /// Arm forward pass into caller buffers: all activations and kernel
    /// scratch come from `ws` (sized by `CapsNetConfig::workspace`); the
    /// final capsule outputs land in `out` (`config.output_len()` long).
    ///
    /// Compatibility wrapper over the execution engine: lowers the uniform
    /// schedule into a [`Program`](crate::exec::Program) and interprets it.
    /// Lowering allocates a small op list per call — serving paths
    /// ([`Device`](crate::coordinator::Device), `Fleet` pool workers,
    /// [`Calibrator`](crate::quant::Calibrator)) lower **once** at bind
    /// time and call [`crate::exec::run_program`] directly, which performs
    /// no heap allocation (asserted by `tests/zero_alloc.rs`). The emitted
    /// event stream is identical to the pre-engine pipelines
    /// (`tests/golden_events.rs`).
    pub fn forward_arm_into<M: Meter>(
        &self,
        input_q: &[i8],
        conv: ArmConv,
        ws: &mut Workspace,
        out: &mut [i8],
        m: &mut M,
    ) {
        let prog = Program::lower_arm_uniform(self, conv, 1);
        run_program(self, &prog, input_q, ws, out, &mut ArmBackend::new(m));
    }

    /// Per-layer scheduled Arm forward pass: `schedule[i]` selects the conv
    /// backend of conv layer `i` and `schedule[convs.len()]` that of the
    /// primary-capsule convolution (capsule layers have no Arm kernel
    /// alternatives). This is the execution surface of [`crate::plan`]
    /// deployment plans, which resolve to such schedules. Bit-identical to
    /// [`Self::forward_arm_into`] for any schedule.
    pub fn forward_arm_scheduled_into<M: Meter>(
        &self,
        input_q: &[i8],
        schedule: &[ArmConv],
        ws: &mut Workspace,
        out: &mut [i8],
        m: &mut M,
    ) {
        let prog = Program::lower_arm(self, schedule, 1);
        run_program(self, &prog, input_q, ws, out, &mut ArmBackend::new(m));
    }

    /// Batch-N Arm forward pass — allocating wrapper over
    /// [`Self::forward_arm_batched_into`].
    pub fn forward_arm_batched<M: Meter>(
        &self,
        inputs_q: &[i8],
        batch: usize,
        conv: ArmConv,
        m: &mut M,
    ) -> Vec<i8> {
        let mut ws = self.config.workspace_batched(batch);
        let mut out = vec![0i8; batch * self.config.output_len()];
        self.forward_arm_batched_into(inputs_q, batch, conv, &mut ws, &mut out, m);
        out
    }

    /// Batch-N Arm forward pass into caller buffers: `inputs_q` holds
    /// `batch` quantized images packed contiguously (`config.input_len()`
    /// apart), `out` receives `batch` capsule outputs
    /// (`config.output_len()` apart). `ws` must come from
    /// `CapsNetConfig::workspace_batched(n)` with `n >= batch` (a
    /// batch-capacity arena serves every smaller batch).
    ///
    /// Every layer runs its batched kernel, which streams the layer's
    /// weights **once per batch** instead of once per image — the
    /// data-movement amortization lever of the paper applied across the
    /// batch dimension. Per-image results are bit-identical to
    /// [`Self::forward_arm_into`] (property-tested), batch 1 included, and
    /// the emitted event stream equals `batch` sequential passes.
    ///
    /// Compatibility wrapper over the execution engine (see
    /// [`Self::forward_arm_into`] for the lowering note); the zero-alloc
    /// serving form is a pre-lowered program run through
    /// [`crate::exec::run_program_batched`].
    pub fn forward_arm_batched_into<M: Meter>(
        &self,
        inputs_q: &[i8],
        batch: usize,
        conv: ArmConv,
        ws: &mut Workspace,
        out: &mut [i8],
        m: &mut M,
    ) {
        assert!(batch >= 1, "batch must be >= 1");
        let prog = Program::lower_arm_uniform(self, conv, batch);
        run_program_batched(self, &prog, inputs_q, batch, ws, out, &mut ArmBackend::new(m));
    }

    /// Batch-N per-layer scheduled Arm forward pass (see
    /// [`Self::forward_arm_scheduled_into`] for the schedule contract and
    /// [`Self::forward_arm_batched_into`] for the batching contract).
    pub fn forward_arm_scheduled_batched_into<M: Meter>(
        &self,
        inputs_q: &[i8],
        batch: usize,
        schedule: &[ArmConv],
        ws: &mut Workspace,
        out: &mut [i8],
        m: &mut M,
    ) {
        assert!(batch >= 1, "batch must be >= 1");
        let prog = Program::lower_arm(self, schedule, batch);
        run_program_batched(self, &prog, inputs_q, batch, ws, out, &mut ArmBackend::new(m));
    }

    /// GAP-8 cluster forward pass — allocating wrapper over
    /// [`Self::forward_riscv_into`].
    pub fn forward_riscv(
        &self,
        input_q: &[i8],
        strategy: PulpConvStrategy,
        run: &mut ClusterRun,
    ) -> Vec<i8> {
        let mut ws = self.config.workspace();
        let mut out = vec![0i8; self.config.output_len()];
        self.forward_riscv_into(input_q, strategy, &mut ws, &mut out, run);
        out
    }

    /// GAP-8 forward pass into caller buffers (see
    /// [`Self::forward_arm_into`] for the buffer and lowering contract).
    /// The pinned strategy runs uniformly on the full executing cluster.
    pub fn forward_riscv_into(
        &self,
        input_q: &[i8],
        strategy: PulpConvStrategy,
        ws: &mut Workspace,
        out: &mut [i8],
        run: &mut ClusterRun,
    ) {
        let prog = Program::lower_riscv_uniform(self, strategy, run.n_cores(), 1);
        run_program(self, &prog, input_q, ws, out, &mut PulpBackend::new(run));
    }

    /// Per-layer scheduled GAP-8 forward pass: `schedule.conv[i]` selects
    /// the PULP strategy **and cluster core split** of conv layer `i`
    /// (`schedule.conv[convs.len()]` covers the primary-capsule
    /// convolution) and `schedule.caps[i]` the core split of capsule layer
    /// `i`. This is the execution surface of [`crate::plan`] deployment
    /// plans: each layer runs as its own fork/join section at exactly the
    /// declared split, so a mixed-split plan is honored by the event meter
    /// layer by layer. Bit-identical to [`Self::forward_riscv_into`] for
    /// any schedule (all strategies and splits compute the same function).
    pub fn forward_riscv_scheduled_into(
        &self,
        input_q: &[i8],
        schedule: &RiscvSchedule,
        ws: &mut Workspace,
        out: &mut [i8],
        run: &mut ClusterRun,
    ) {
        let prog = Program::lower_riscv(self, schedule, 1);
        run_program(self, &prog, input_q, ws, out, &mut PulpBackend::new(run));
    }

    /// Batch-N GAP-8 forward pass — allocating wrapper over
    /// [`Self::forward_riscv_batched_into`].
    pub fn forward_riscv_batched(
        &self,
        inputs_q: &[i8],
        batch: usize,
        strategy: PulpConvStrategy,
        run: &mut ClusterRun,
    ) -> Vec<i8> {
        let mut ws = self.config.workspace_batched(batch);
        let mut out = vec![0i8; batch * self.config.output_len()];
        self.forward_riscv_batched_into(inputs_q, batch, strategy, &mut ws, &mut out, run);
        out
    }

    /// Batch-N GAP-8 forward pass into caller buffers (see
    /// [`Self::forward_arm_batched_into`] for the batching contract).
    pub fn forward_riscv_batched_into(
        &self,
        inputs_q: &[i8],
        batch: usize,
        strategy: PulpConvStrategy,
        ws: &mut Workspace,
        out: &mut [i8],
        run: &mut ClusterRun,
    ) {
        assert!(batch >= 1, "batch must be >= 1");
        let prog = Program::lower_riscv_uniform(self, strategy, run.n_cores(), batch);
        run_program_batched(self, &prog, inputs_q, batch, ws, out, &mut PulpBackend::new(run));
    }

    /// Batch-N per-layer scheduled GAP-8 forward pass (see
    /// [`Self::forward_riscv_scheduled_into`] for the schedule contract and
    /// [`Self::forward_riscv_batched_into`] for the batching contract).
    pub fn forward_riscv_scheduled_batched_into(
        &self,
        inputs_q: &[i8],
        batch: usize,
        schedule: &RiscvSchedule,
        ws: &mut Workspace,
        out: &mut [i8],
        run: &mut ClusterRun,
    ) {
        assert!(batch >= 1, "batch must be >= 1");
        let prog = Program::lower_riscv(self, schedule, batch);
        run_program_batched(self, &prog, inputs_q, batch, ws, out, &mut PulpBackend::new(run));
    }

    /// Predicted class: capsule with the largest vector norm (the vector
    /// length encodes class probability — paper §2.2).
    pub fn classify(&self, caps_out: &[i8]) -> usize {
        let dim = self.config.caps_layers.last().map(|l| l.cap_dim).unwrap_or(1);
        let n = caps_out.len() / dim;
        (0..n)
            .max_by_key(|&j| {
                caps_out[j * dim..(j + 1) * dim]
                    .iter()
                    .map(|&x| (x as i64) * (x as i64))
                    .sum::<i64>()
            })
            .unwrap_or(0)
    }

    /// Build a randomly-weighted model for tests/benches (valid shifts,
    /// plausible formats).
    pub fn random(config: CapsNetConfig, seed: u64) -> Self {
        use crate::testing::prop::XorShift;
        let mut rng = XorShift::new(seed);
        let convs = (0..config.conv_layers.len())
            .map(|i| {
                let d = config.conv_dims(i);
                QConvLayer {
                    w: rng.i8_vec(d.weight_len()),
                    b: rng.i8_vec(d.out_ch),
                    bias_shift: 0,
                    out_shift: 7,
                }
            })
            .collect();
        let pd = config.pcap_dims();
        let pcap = QPcapLayer {
            w: rng.i8_vec(pd.conv.weight_len()),
            b: rng.i8_vec(pd.conv.out_ch),
            shifts: PcapShifts {
                bias_shift: 0,
                out_shift: 8,
                squash: SquashParams::q7_out(6),
            },
        };
        let caps = (0..config.caps_layers.len())
            .map(|i| {
                let d = config.caps_dims(i);
                let r = config.caps_layers[i].routings;
                QCapsLayer {
                    w: rng.i8_vec(d.weight_len()),
                    shifts: CapsuleShifts::uniform(r, 7, 6),
                }
            })
            .collect();
        QuantizedCapsNet { config, input_qn: 7, convs, pcap, caps }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{CostModel, NullMeter};
    use crate::model::config::configs;
    use crate::testing::prop::XorShift;

    #[test]
    fn archive_roundtrip() {
        let net = QuantizedCapsNet::random(configs::cifar10(), 42);
        let a = net.to_archive();
        let back = QuantizedCapsNet::from_archive(&a).unwrap();
        assert_eq!(back.config, net.config);
        assert_eq!(back.input_qn, net.input_qn);
        assert_eq!(back.pcap.w, net.pcap.w);
        assert_eq!(back.caps[0].shifts, net.caps[0].shifts);
    }

    #[test]
    fn forward_shapes() {
        let net = QuantizedCapsNet::random(configs::mnist(), 1);
        let mut rng = XorShift::new(2);
        let input = rng.i8_vec(net.config.input_len());
        let out = net.forward_arm(&input, ArmConv::Basic, &mut NullMeter);
        assert_eq!(out.len(), 10 * 6);
        let cls = net.classify(&out);
        assert!(cls < 10);
    }

    #[test]
    fn arm_and_riscv_forward_bit_equal() {
        // Full-network cross-ISA equivalence — the strongest single check
        // that every kernel pair agrees.
        let net = QuantizedCapsNet::random(configs::cifar10(), 7);
        let mut rng = XorShift::new(8);
        let input = rng.i8_vec(net.config.input_len());
        let arm = net.forward_arm(&input, ArmConv::FastWithFallback, &mut NullMeter);
        let arm_basic = net.forward_arm(&input, ArmConv::Basic, &mut NullMeter);
        assert_eq!(arm, arm_basic);
        for cores in [1usize, 8] {
            let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), cores);
            let rv = net.forward_riscv(&input, PulpConvStrategy::HoWo, &mut run);
            assert_eq!(rv, arm, "cores={cores}");
        }
    }

    #[test]
    fn forward_into_matches_wrappers_across_random_configs() {
        // Satellite property: the zero-alloc `_into` entry points are
        // bit-equal to the allocating wrappers for arbitrary architectures,
        // including workspace reuse across calls and both ISAs.
        use crate::testing::prop::{rand_config, Prop};
        Prop::new("forward into == wrapper", 25).run(|rng| {
            let cfg = rand_config(rng);
            let net = QuantizedCapsNet::random(cfg, rng.next_u64());
            let input = rng.i8_vec(net.config.input_len());
            let expected = net.forward_arm(&input, ArmConv::FastWithFallback, &mut NullMeter);
            let mut ws = net.config.workspace();
            let mut out = vec![0i8; net.config.output_len()];
            // same workspace twice — stale scratch must not leak into results
            for pass in 0..2 {
                net.forward_arm_into(
                    &input, ArmConv::FastWithFallback, &mut ws, &mut out, &mut NullMeter,
                );
                assert_eq!(out, expected, "arm pass {pass}");
            }
            for cores in [1usize, 8] {
                let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), cores);
                net.forward_riscv_into(&input, PulpConvStrategy::HoWo, &mut ws, &mut out, &mut run);
                assert_eq!(out, expected, "riscv cores={cores}");
            }
        });
    }

    #[test]
    fn batched_forward_bit_equals_sequential_across_random_configs() {
        // Tentpole property: `forward_*_batched_into` over N images is
        // bit-identical to N independent `forward_*_into` calls — including
        // batch 1 vs the batch-1 path, arena reuse across batches, partial
        // batches in a larger arena, and both ISAs.
        use crate::testing::prop::{rand_config, Prop};
        Prop::new("batched forward == sequential", 15).run(|rng| {
            let cfg = rand_config(rng);
            let net = QuantizedCapsNet::random(cfg, rng.next_u64());
            let in_len = net.config.input_len();
            let out_len = net.config.output_len();
            let capacity = 4usize;
            let batch = rng.range(1, capacity);
            let inputs = rng.i8_vec(batch * in_len);

            // sequential reference
            let mut seq = vec![0i8; batch * out_len];
            let mut ws1 = net.config.workspace();
            for img in 0..batch {
                net.forward_arm_into(
                    &inputs[img * in_len..(img + 1) * in_len], ArmConv::FastWithFallback,
                    &mut ws1, &mut seq[img * out_len..(img + 1) * out_len], &mut NullMeter,
                );
            }

            // batch-capacity arena serves the (possibly partial) batch, twice
            // to prove stale scratch doesn't leak between batches
            let mut ws = net.config.workspace_batched(capacity);
            let mut out = vec![0i8; batch * out_len];
            for pass in 0..2 {
                net.forward_arm_batched_into(
                    &inputs, batch, ArmConv::FastWithFallback, &mut ws, &mut out, &mut NullMeter,
                );
                assert_eq!(out, seq, "arm batch {batch} pass {pass}");
            }
            for cores in [1usize, 8] {
                let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), cores);
                net.forward_riscv_batched_into(
                    &inputs, batch, PulpConvStrategy::HoWo, &mut ws, &mut out, &mut run,
                );
                assert_eq!(out, seq, "riscv batch {batch} cores {cores}");
            }
        });
    }

    #[test]
    fn batched_forward_event_totals_match_sequential() {
        // The batch amortization must not change the simulated cost story:
        // a batch-N metered pass emits exactly N passes' events.
        let net = QuantizedCapsNet::random(configs::mnist(), 11);
        let mut rng = XorShift::new(12);
        let batch = 3;
        let inputs = rng.i8_vec(batch * net.config.input_len());
        let out_len = net.config.output_len();
        let mut seq_cc = crate::isa::CycleCounter::new(CostModel::cortex_m4());
        let mut ws1 = net.config.workspace();
        let mut out = vec![0i8; out_len];
        for img in 0..batch {
            let lo = img * net.config.input_len();
            net.forward_arm_into(
                &inputs[lo..lo + net.config.input_len()], ArmConv::FastWithFallback, &mut ws1,
                &mut out, &mut seq_cc,
            );
        }
        let mut cc = crate::isa::CycleCounter::new(CostModel::cortex_m4());
        let mut ws = net.config.workspace_batched(batch);
        let mut bout = vec![0i8; batch * out_len];
        net.forward_arm_batched_into(
            &inputs, batch, ArmConv::FastWithFallback, &mut ws, &mut bout, &mut cc,
        );
        assert_eq!(cc.counts(), seq_cc.counts());
        assert_eq!(cc.cycles(), seq_cc.cycles());
    }

    #[test]
    fn scheduled_forwards_match_pinned_strategy() {
        // The per-layer scheduled entry points (the execution surface of
        // deployment plans) are bit-identical to the pinned-strategy paths
        // for any schedule, since every kernel variant computes the same
        // function — batch-1 and batched, both ISAs, mixed strategies AND
        // mixed core splits.
        let net = QuantizedCapsNet::random(configs::cifar10(), 21);
        let mut rng = XorShift::new(22);
        let input = rng.i8_vec(net.config.input_len());
        let expected = net.forward_arm(&input, ArmConv::FastWithFallback, &mut NullMeter);
        let n_sched = net.convs.len() + 1;
        let sched: Vec<ArmConv> = (0..n_sched)
            .map(|i| if i % 2 == 0 { ArmConv::Basic } else { ArmConv::FastWithFallback })
            .collect();
        let mut ws = net.config.workspace();
        let mut out = vec![0i8; net.config.output_len()];
        net.forward_arm_scheduled_into(&input, &sched, &mut ws, &mut out, &mut NullMeter);
        assert_eq!(out, expected, "arm scheduled");
        use crate::kernels::conv::PulpConvStrategy as S;
        let rsched = RiscvSchedule {
            conv: (0..n_sched)
                .map(|i| PulpLayerExec {
                    strategy: [S::Co, S::Ho, S::HoWo][i % 3],
                    cores: [8usize, 4, 2, 1][i % 4],
                })
                .collect(),
            caps: (0..net.caps.len()).map(|i| [4usize, 1, 8][i % 3]).collect(),
        };
        let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
        net.forward_riscv_scheduled_into(&input, &rsched, &mut ws, &mut out, &mut run);
        assert_eq!(out, expected, "riscv scheduled mixed-split");

        let batch = 3;
        let inputs = rng.i8_vec(batch * net.config.input_len());
        let mut wsb = net.config.workspace_batched(batch);
        let mut outb = vec![0i8; batch * net.config.output_len()];
        let mut outb2 = vec![0i8; batch * net.config.output_len()];
        net.forward_arm_batched_into(
            &inputs, batch, ArmConv::FastWithFallback, &mut wsb, &mut outb, &mut NullMeter,
        );
        net.forward_arm_scheduled_batched_into(
            &inputs, batch, &sched, &mut wsb, &mut outb2, &mut NullMeter,
        );
        assert_eq!(outb2, outb, "arm scheduled batched");
        let mut run2 = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
        net.forward_riscv_scheduled_batched_into(
            &inputs, batch, &rsched, &mut wsb, &mut outb2, &mut run2,
        );
        assert_eq!(outb2, outb, "riscv scheduled batched mixed-split");
    }

    #[test]
    fn uniform_schedule_equals_pinned_events_per_core() {
        // A uniform full-cluster schedule is the pinned path expressed as a
        // schedule: per-core event counts and cluster cycles must be
        // identical, so plan-driven execution inherits the golden event
        // streams (`tests/golden_events.rs`) transitively.
        let net = QuantizedCapsNet::random(configs::cifar10(), 23);
        let mut rng = XorShift::new(24);
        let input = rng.i8_vec(net.config.input_len());
        let mut ws = net.config.workspace();
        let mut out = vec![0i8; net.config.output_len()];
        let model = CostModel::gap8_cluster_core();
        let mut pinned = ClusterRun::new(&model, 8);
        net.forward_riscv_into(&input, PulpConvStrategy::HoWo, &mut ws, &mut out, &mut pinned);
        let pinned_out = out.clone();
        let sched =
            RiscvSchedule::uniform(PulpConvStrategy::HoWo, 8, net.convs.len(), net.caps.len());
        let mut scheduled = ClusterRun::new(&model, 8);
        net.forward_riscv_scheduled_into(&input, &sched, &mut ws, &mut out, &mut scheduled);
        assert_eq!(out, pinned_out);
        for (c, (a, b)) in pinned.cores.iter().zip(scheduled.cores.iter()).enumerate() {
            assert_eq!(a.counts(), b.counts(), "core {c}");
        }
        assert_eq!(pinned.cycles(), scheduled.cycles());
    }

    #[test]
    fn quantize_input_clips() {
        let net = QuantizedCapsNet::random(configs::mnist(), 3);
        // input_qn = 7 → scale 128
        let q = net.quantize_input(&[0.0, 0.5, 1.0, -1.0, 100.0]);
        assert_eq!(q, vec![0, 64, 127, -128, 127]);
    }

    #[test]
    fn classify_picks_longest_vector() {
        let net = QuantizedCapsNet::random(configs::mnist(), 4);
        let mut out = vec![0i8; 60];
        out[3 * 6..4 * 6].copy_from_slice(&[50, 50, 50, 50, 50, 50]);
        out[7 * 6..8 * 6].copy_from_slice(&[10, 0, 0, 0, 0, 0]);
        assert_eq!(net.classify(&out), 3);
    }

    #[test]
    fn load_rejects_wrong_sizes() {
        let net = QuantizedCapsNet::random(configs::mnist(), 5);
        let mut a = net.to_archive();
        a.insert("conv0.w", Tensor::I8 { dims: vec![3], data: vec![1, 2, 3] });
        assert!(QuantizedCapsNet::from_archive(&a).is_err());
    }
}

#[cfg(test)]
mod deep_tests {
    use super::*;
    use crate::isa::{ClusterRun, CostModel, NullMeter};
    use crate::kernels::conv::PulpConvStrategy;
    use crate::model::config::{CapsLayerCfg, CapsNetConfig, ConvLayerCfg, PcapCfg};
    use crate::testing::prop::XorShift;

    /// A deeper variant with two chained capsule layers — the paper's
    /// architecture description allows "a single or multiple capsule
    /// layer[s]" (§2.2); this exercises the chaining path.
    fn deep_config() -> CapsNetConfig {
        CapsNetConfig {
            name: "mnist-deep".into(),
            input: [28, 28, 1],
            conv_layers: vec![ConvLayerCfg { filters: 16, kernel: 7, stride: 1, pad: 0, relu: true }],
            pcap: PcapCfg { num_caps: 16, cap_dim: 4, kernel: 7, stride: 2, pad: 0 },
            caps_layers: vec![
                CapsLayerCfg { num_caps: 24, cap_dim: 6, routings: 2 },
                CapsLayerCfg { num_caps: 10, cap_dim: 6, routings: 3 },
            ],
        }
    }

    #[test]
    fn deep_config_shapes_chain() {
        let cfg = deep_config();
        let d0 = cfg.caps_dims(0);
        assert_eq!((d0.in_caps, d0.in_dim, d0.out_caps, d0.out_dim), (1024, 4, 24, 6));
        let d1 = cfg.caps_dims(1);
        assert_eq!((d1.in_caps, d1.in_dim, d1.out_caps, d1.out_dim), (24, 6, 10, 6));
        assert_eq!(cfg.num_classes(), 10);
    }

    #[test]
    fn deep_forward_runs_and_backends_agree() {
        let net = QuantizedCapsNet::random(deep_config(), 31);
        let mut rng = XorShift::new(32);
        let input = rng.i8_vec(net.config.input_len());
        let arm = net.forward_arm(&input, ArmConv::FastWithFallback, &mut NullMeter);
        assert_eq!(arm.len(), 10 * 6);
        let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
        let rv = net.forward_riscv(&input, PulpConvStrategy::HoWo, &mut run);
        assert_eq!(rv, arm);
    }

    #[test]
    fn deep_archive_roundtrip() {
        let net = QuantizedCapsNet::random(deep_config(), 33);
        let back = QuantizedCapsNet::from_archive(&net.to_archive()).unwrap();
        assert_eq!(back.caps.len(), 2);
        assert_eq!(back.caps[1].w, net.caps[1].w);
        assert_eq!(back.config.caps_layers[0].routings, 2);
    }

    #[test]
    fn deep_model_footprint_accounts_both_layers() {
        let cfg = deep_config();
        let shallow = crate::model::configs::mnist();
        assert!(cfg.num_params() > shallow.num_params());
        assert!(cfg.peak_activation_bytes() >= shallow.peak_activation_bytes());
    }
}
