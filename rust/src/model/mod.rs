//! CapsNet model definitions and inference engines.
//!
//! * [`config`] — architecture configs (paper Table 1) + JSON schema shared
//!   with the Python build step.
//! * [`quantized`] — int-8 engine over the instrumented kernels (`.cnq`
//!   artifacts).
//! * [`float`] — f32 reference engine mirroring the JAX model.

pub mod config;
pub mod float;
pub mod quantized;

pub use config::{configs, CapsLayerCfg, CapsNetConfig, ConvLayerCfg, PcapCfg};
pub use float::FloatCapsNet;
pub use quantized::{
    ArmConv, PulpLayerExec, QCapsLayer, QConvLayer, QPcapLayer, QuantizedCapsNet, RiscvSchedule,
};
