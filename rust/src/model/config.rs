//! CapsNet architecture configuration (paper Table 1).
//!
//! The three reference models are built-in ([`configs`]); arbitrary models
//! load from the JSON mirror embedded in `.cnq` archives (written by
//! `python/compile/configs.py` — the two sides share the JSON schema).

use crate::formats::JsonValue;
use crate::kernels::capsule::CapsuleDims;
use crate::kernels::conv::ConvDims;
use crate::kernels::pcap::PcapDims;
use anyhow::{bail, Context, Result};

/// One convolutional feature-extraction layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvLayerCfg {
    pub filters: usize,
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
    pub relu: bool,
}

/// The primary capsule layer (paper §3.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PcapCfg {
    pub num_caps: usize,
    pub cap_dim: usize,
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
}

/// A (class) capsule layer with dynamic routing (paper §3.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CapsLayerCfg {
    pub num_caps: usize,
    pub cap_dim: usize,
    pub routings: usize,
}

/// Full network architecture.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CapsNetConfig {
    pub name: String,
    /// Input shape `[h, w, c]`.
    pub input: [usize; 3],
    pub conv_layers: Vec<ConvLayerCfg>,
    pub pcap: PcapCfg,
    pub caps_layers: Vec<CapsLayerCfg>,
}

impl CapsNetConfig {
    /// Geometry of conv layer `i` given the propagated input shape.
    pub fn conv_dims(&self, i: usize) -> ConvDims {
        let (h, w, c) = self.shape_before_conv(i);
        let l = &self.conv_layers[i];
        ConvDims {
            in_h: h,
            in_w: w,
            in_ch: c,
            out_ch: l.filters,
            k_h: l.kernel,
            k_w: l.kernel,
            stride: l.stride,
            pad: l.pad,
        }
    }

    fn shape_before_conv(&self, i: usize) -> (usize, usize, usize) {
        let mut h = self.input[0];
        let mut w = self.input[1];
        let mut c = self.input[2];
        for l in &self.conv_layers[..i] {
            h = (h + 2 * l.pad - l.kernel) / l.stride + 1;
            w = (w + 2 * l.pad - l.kernel) / l.stride + 1;
            c = l.filters;
        }
        (h, w, c)
    }

    /// Geometry of the primary capsule layer.
    pub fn pcap_dims(&self) -> PcapDims {
        let (h, w, c) = self.shape_before_conv(self.conv_layers.len());
        PcapDims {
            conv: ConvDims {
                in_h: h,
                in_w: w,
                in_ch: c,
                out_ch: self.pcap.num_caps * self.pcap.cap_dim,
                k_h: self.pcap.kernel,
                k_w: self.pcap.kernel,
                stride: self.pcap.stride,
                pad: self.pcap.pad,
            },
            num_caps: self.pcap.num_caps,
            cap_dim: self.pcap.cap_dim,
        }
    }

    /// Geometry of capsule layer `i` (chained after the primary capsules).
    pub fn caps_dims(&self, i: usize) -> CapsuleDims {
        let (mut in_caps, mut in_dim) = {
            let p = self.pcap_dims();
            (p.total_caps(), p.cap_dim)
        };
        for l in &self.caps_layers[..i] {
            in_caps = l.num_caps;
            in_dim = l.cap_dim;
        }
        let l = &self.caps_layers[i];
        CapsuleDims {
            in_caps,
            in_dim,
            out_caps: l.num_caps,
            out_dim: l.cap_dim,
        }
    }

    /// Classes = capsules of the last layer.
    pub fn num_classes(&self) -> usize {
        self.caps_layers.last().map(|l| l.num_caps).unwrap_or(0)
    }

    pub fn input_len(&self) -> usize {
        self.input.iter().product()
    }

    /// Length of the forward pass's final output: the last capsule layer's
    /// `[num_caps × cap_dim]`, or the primary-capsule output for a (degenerate)
    /// config with no capsule layers.
    pub fn output_len(&self) -> usize {
        match self.caps_layers.last() {
            Some(l) => l.num_caps * l.cap_dim,
            None => self.pcap_dims().out_len(),
        }
    }

    /// Largest activation buffer any layer boundary needs (network input
    /// included) — the ping-pong buffers of the zero-alloc forward path are
    /// each this long.
    pub fn max_activation_len(&self) -> usize {
        let mut peak = self.input_len();
        for i in 0..self.conv_layers.len() {
            peak = peak.max(self.conv_dims(i).out_len());
        }
        peak = peak.max(self.pcap_dims().out_len());
        for i in 0..self.caps_layers.len() {
            peak = peak.max(self.caps_dims(i).output_len());
        }
        peak
    }

    /// Largest per-layer kernel scratch (im2col buffers, capsule routing
    /// temporaries + matmul transpose scratch) across the network.
    pub fn max_kernel_scratch_len(&self) -> usize {
        self.max_kernel_scratch_len_batched(1)
    }

    /// Largest per-layer kernel scratch for a batch of `n` images (see the
    /// `scratch_len_batched` methods on the kernel geometry types).
    pub fn max_kernel_scratch_len_batched(&self, n: usize) -> usize {
        let mut peak = 0usize;
        for i in 0..self.conv_layers.len() {
            peak = peak.max(self.conv_dims(i).scratch_len_batched(n));
        }
        peak = peak.max(self.pcap_dims().scratch_len_batched(n));
        for i in 0..self.caps_layers.len() {
            peak = peak.max(self.caps_dims(i).scratch_len_batched(n));
        }
        peak
    }

    /// Total `i8` workspace the zero-alloc forward path carves: two
    /// ping-pong activation buffers plus the largest kernel scratch.
    pub fn scratch_i8_len(&self) -> usize {
        2 * self.max_activation_len() + self.max_kernel_scratch_len()
    }

    /// Total `i8` workspace `forward_*_batched_into` carves for a batch of
    /// `n` images: two batch-wide ping-pong activation slabs (each `n ×`
    /// [`Self::max_activation_len`], images packed contiguously at the
    /// layer's activation stride) plus the largest batched kernel scratch.
    /// `scratch_i8_len_batched(1) == scratch_i8_len()` by construction.
    pub fn scratch_i8_len_batched(&self, n: usize) -> usize {
        2 * n * self.max_activation_len() + self.max_kernel_scratch_len_batched(n)
    }

    /// Build a [`Workspace`](crate::kernels::workspace::Workspace) sized for
    /// this model's `forward_*_into` — allocate once, reuse per inference.
    pub fn workspace(&self) -> crate::kernels::workspace::Workspace {
        crate::kernels::workspace::Workspace::with_capacity(self.scratch_i8_len())
    }

    /// Build a workspace sized for `forward_*_batched_into` with batches of
    /// up to `n` images — allocate once per worker, reuse per batch. A
    /// batch-`n` arena also serves every smaller batch (the carver takes a
    /// prefix), so one resident arena covers partial final batches.
    pub fn workspace_batched(&self, n: usize) -> crate::kernels::workspace::Workspace {
        crate::kernels::workspace::Workspace::with_capacity(self.scratch_i8_len_batched(n))
    }

    /// Total learnable parameters (weights + biases).
    pub fn num_params(&self) -> usize {
        let mut n = 0;
        for i in 0..self.conv_layers.len() {
            let d = self.conv_dims(i);
            n += d.weight_len() + d.out_ch;
        }
        let p = self.pcap_dims();
        n += p.conv.weight_len() + p.conv.out_ch;
        for i in 0..self.caps_layers.len() {
            n += self.caps_dims(i).weight_len();
        }
        n
    }

    /// Number of auxiliary shift/format parameters the quantized model
    /// carries (stored as i32) — the paper counts these in the int-8
    /// footprint (§5.1: "we consider these parameters part of the memory
    /// footprint inherent to the quantized CapsNet").
    pub fn num_shift_params(&self) -> usize {
        let mut n = 1; // input_qn
        n += self.conv_layers.len() * 2; // bias + out shift each
        n += 3; // pcap bias, out, squash_in_qn
        for l in &self.caps_layers {
            let r = l.routings;
            n += 1 + r + r + (r - 1) + (r - 1); // inputs_hat, caps_out, squash, agreement, logit_acc
        }
        n
    }

    /// Float-32 model footprint in bytes (paper Table 2 left column).
    pub fn float_bytes(&self) -> usize {
        self.num_params() * 4
    }

    /// Int-8 model footprint in bytes, including shift parameters
    /// (Table 2 middle column).
    pub fn int8_bytes(&self) -> usize {
        self.num_params() + self.num_shift_params() * 4
    }

    /// Peak activation working set in bytes for int-8 inference (input
    /// buffer + largest layer in/out pair + routing temporaries).
    pub fn peak_activation_bytes(&self) -> usize {
        let mut peak = 0usize;
        let mut prev = self.input_len();
        for i in 0..self.conv_layers.len() {
            let out = self.conv_dims(i).out_len();
            peak = peak.max(prev + out);
            prev = out;
        }
        let p = self.pcap_dims();
        peak = peak.max(prev + p.out_len());
        prev = p.out_len();
        for i in 0..self.caps_layers.len() {
            let d = self.caps_dims(i);
            // û dominates: [out_caps, in_caps, out_dim] + logits + coupling.
            let routing = d.uhat_len() + 2 * d.logit_len() + d.output_len();
            peak = peak.max(prev + routing);
            prev = d.output_len();
        }
        peak
    }

    /// Total deployed footprint: model + peak activations.
    pub fn deployed_bytes(&self) -> usize {
        self.int8_bytes() + self.peak_activation_bytes()
    }

    /// Deployed footprint of a batch-`n` execution arena: model bytes plus
    /// the whole batched interpreter workspace
    /// ([`Self::scratch_i8_len_batched`]) — the number a board's RAM must
    /// cover before profiling or serving a batch-`n` program on it.
    /// `deployed_bytes_batched(1) ≥ deployed_bytes()` (the arena carries
    /// kernel scratch the peak-activation estimate does not).
    pub fn deployed_bytes_batched(&self, n: usize) -> usize {
        self.int8_bytes() + self.scratch_i8_len_batched(n)
    }

    // -- JSON (shared schema with python/compile/configs.py) ----------------

    pub fn to_json(&self) -> JsonValue {
        JsonValue::obj(vec![
            ("name", JsonValue::str(&self.name)),
            (
                "input",
                JsonValue::Array(self.input.iter().map(|&d| JsonValue::int(d as i64)).collect()),
            ),
            (
                "conv_layers",
                JsonValue::Array(
                    self.conv_layers
                        .iter()
                        .map(|l| {
                            JsonValue::obj(vec![
                                ("filters", JsonValue::int(l.filters as i64)),
                                ("kernel", JsonValue::int(l.kernel as i64)),
                                ("stride", JsonValue::int(l.stride as i64)),
                                ("pad", JsonValue::int(l.pad as i64)),
                                ("relu", JsonValue::Bool(l.relu)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "pcap",
                JsonValue::obj(vec![
                    ("num_caps", JsonValue::int(self.pcap.num_caps as i64)),
                    ("cap_dim", JsonValue::int(self.pcap.cap_dim as i64)),
                    ("kernel", JsonValue::int(self.pcap.kernel as i64)),
                    ("stride", JsonValue::int(self.pcap.stride as i64)),
                    ("pad", JsonValue::int(self.pcap.pad as i64)),
                ]),
            ),
            (
                "caps_layers",
                JsonValue::Array(
                    self.caps_layers
                        .iter()
                        .map(|l| {
                            JsonValue::obj(vec![
                                ("num_caps", JsonValue::int(l.num_caps as i64)),
                                ("cap_dim", JsonValue::int(l.cap_dim as i64)),
                                ("routings", JsonValue::int(l.routings as i64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &JsonValue) -> Result<CapsNetConfig> {
        let name = v.req("name")?.as_str()?.to_string();
        let input_v = v.req("input")?.as_usize_vec()?;
        if input_v.len() != 3 {
            bail!("input must be [h, w, c]");
        }
        let conv_layers = v
            .req("conv_layers")?
            .as_array()?
            .iter()
            .map(|l| {
                Ok(ConvLayerCfg {
                    filters: l.req("filters")?.as_usize()?,
                    kernel: l.req("kernel")?.as_usize()?,
                    stride: l.req("stride")?.as_usize()?,
                    pad: l.get("pad").map(|p| p.as_usize()).transpose()?.unwrap_or(0),
                    relu: l.get("relu").map(|r| r.as_bool()).transpose()?.unwrap_or(true),
                })
            })
            .collect::<Result<Vec<_>>>()
            .context("conv_layers")?;
        let p = v.req("pcap")?;
        let pcap = PcapCfg {
            num_caps: p.req("num_caps")?.as_usize()?,
            cap_dim: p.req("cap_dim")?.as_usize()?,
            kernel: p.req("kernel")?.as_usize()?,
            stride: p.req("stride")?.as_usize()?,
            pad: p.get("pad").map(|x| x.as_usize()).transpose()?.unwrap_or(0),
        };
        let caps_layers = v
            .req("caps_layers")?
            .as_array()?
            .iter()
            .map(|l| {
                Ok(CapsLayerCfg {
                    num_caps: l.req("num_caps")?.as_usize()?,
                    cap_dim: l.req("cap_dim")?.as_usize()?,
                    routings: l.req("routings")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()
            .context("caps_layers")?;
        Ok(CapsNetConfig {
            name,
            input: [input_v[0], input_v[1], input_v[2]],
            conv_layers,
            pcap,
            caps_layers,
        })
    }
}

/// The paper's three reference CapsNets (Table 1).
pub mod configs {
    use super::*;

    /// MNIST: conv(16, k7, s1, ReLU) → pcap(16 caps × 4, k7, s2)
    /// → caps(10 × 6, r3). Capsule workload 10×1024×6×4 (Table 7).
    pub fn mnist() -> CapsNetConfig {
        CapsNetConfig {
            name: "mnist".into(),
            input: [28, 28, 1],
            conv_layers: vec![ConvLayerCfg { filters: 16, kernel: 7, stride: 1, pad: 0, relu: true }],
            pcap: PcapCfg { num_caps: 16, cap_dim: 4, kernel: 7, stride: 2, pad: 0 },
            caps_layers: vec![CapsLayerCfg { num_caps: 10, cap_dim: 6, routings: 3 }],
        }
    }

    /// smallNORB: conv(32, k7, s1, ReLU) → pcap(16 × 4, k7, s2)
    /// → caps(5 × 6, r3).
    ///
    /// Input is 32×32×2: the paper lists the raw dataset as 96×96×2, but
    /// its own capsule workload (5×1600×6×4, Table 8) pins the primary
    /// capsule grid to 10×10 — i.e. a 32×32 network input, consistent with
    /// the standard smallNORB resize-48/crop-32 pipeline (DESIGN.md §2).
    pub fn smallnorb() -> CapsNetConfig {
        CapsNetConfig {
            name: "smallnorb".into(),
            input: [32, 32, 2],
            conv_layers: vec![ConvLayerCfg { filters: 32, kernel: 7, stride: 1, pad: 0, relu: true }],
            pcap: PcapCfg { num_caps: 16, cap_dim: 4, kernel: 7, stride: 2, pad: 0 },
            caps_layers: vec![CapsLayerCfg { num_caps: 5, cap_dim: 6, routings: 3 }],
        }
    }

    /// CIFAR-10: conv(32,k3,s1) ×2 … conv(64,k3,s2) ×2 → pcap(16 × 4, k3, s2)
    /// → caps(10 × 5, r3). Capsule workload 10×64×5×4 (Table 7).
    pub fn cifar10() -> CapsNetConfig {
        CapsNetConfig {
            name: "cifar10".into(),
            input: [32, 32, 3],
            conv_layers: vec![
                ConvLayerCfg { filters: 32, kernel: 3, stride: 1, pad: 0, relu: true },
                ConvLayerCfg { filters: 32, kernel: 3, stride: 1, pad: 0, relu: true },
                ConvLayerCfg { filters: 64, kernel: 3, stride: 2, pad: 0, relu: true },
                ConvLayerCfg { filters: 64, kernel: 3, stride: 2, pad: 0, relu: true },
            ],
            pcap: PcapCfg { num_caps: 16, cap_dim: 4, kernel: 3, stride: 2, pad: 0 },
            caps_layers: vec![CapsLayerCfg { num_caps: 10, cap_dim: 5, routings: 3 }],
        }
    }

    pub fn all() -> Vec<CapsNetConfig> {
        vec![mnist(), smallnorb(), cifar10()]
    }

    pub fn by_name(name: &str) -> Option<CapsNetConfig> {
        match name {
            "mnist" => Some(mnist()),
            "smallnorb" => Some(smallnorb()),
            "cifar10" => Some(cifar10()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::configs::*;
    use super::*;

    #[test]
    fn mnist_capsule_workload_matches_table7() {
        // Table 7 row: MNIST capsule layer is 10×1024×6×4.
        let d = mnist().caps_dims(0);
        assert_eq!((d.out_caps, d.in_caps, d.out_dim, d.in_dim), (10, 1024, 6, 4));
    }

    #[test]
    fn smallnorb_capsule_workload_matches_table8() {
        let d = smallnorb().caps_dims(0);
        assert_eq!((d.out_caps, d.in_caps, d.out_dim, d.in_dim), (5, 1600, 6, 4));
    }

    #[test]
    fn cifar_capsule_workload_matches_table7() {
        let d = cifar10().caps_dims(0);
        assert_eq!((d.out_caps, d.in_caps, d.out_dim, d.in_dim), (10, 64, 5, 4));
    }

    #[test]
    fn pcap_kernels_match_table5_labels() {
        // Table 5 labels: MNIST 7x7x16(x64), smallNORB 7x7x32, CIFAR 3x3x64.
        let m = mnist().pcap_dims();
        assert_eq!((m.conv.k_h, m.conv.in_ch, m.conv.out_ch), (7, 16, 64));
        let s = smallnorb().pcap_dims();
        assert_eq!((s.conv.k_h, s.conv.in_ch, s.conv.out_ch), (7, 32, 64));
        let c = cifar10().pcap_dims();
        assert_eq!((c.conv.k_h, c.conv.in_ch, c.conv.out_ch), (3, 64, 64));
    }

    #[test]
    fn memory_saving_is_75_percent() {
        // Table 2: int-8 saving is 74.99% for all three models.
        for cfg in all() {
            let saving = 1.0 - cfg.int8_bytes() as f64 / cfg.float_bytes() as f64;
            assert!(
                (0.7485..0.7501).contains(&saving),
                "{}: saving {saving:.4} ({} → {})",
                cfg.name,
                cfg.float_bytes(),
                cfg.int8_bytes()
            );
        }
    }

    #[test]
    fn deployed_models_fit_paper_boards() {
        // Paper §5: every quantized net + activations fits ≤80% RAM of the
        // smallest board (512 KB).
        for cfg in all() {
            let total = cfg.deployed_bytes();
            assert!(
                total <= 512 * 1024 * 8 / 10,
                "{}: deployed {total} bytes exceeds 80% of 512 KB",
                cfg.name
            );
        }
    }

    #[test]
    fn workspace_sizing_covers_reference_models() {
        for cfg in all() {
            assert!(cfg.max_activation_len() >= cfg.input_len());
            assert!(cfg.max_kernel_scratch_len() > 0, "{}", cfg.name);
            assert_eq!(
                cfg.scratch_i8_len(),
                2 * cfg.max_activation_len() + cfg.max_kernel_scratch_len()
            );
            let ws = cfg.workspace();
            assert_eq!(ws.i8_capacity(), cfg.scratch_i8_len());
            assert_eq!(cfg.output_len(), cfg.num_classes() * cfg.caps_layers.last().unwrap().cap_dim);
        }
    }

    #[test]
    fn batched_sizing_extends_batch1_contract() {
        for cfg in all() {
            // batch 1 is exactly the existing single-image contract
            assert_eq!(cfg.scratch_i8_len_batched(1), cfg.scratch_i8_len());
            assert_eq!(cfg.max_kernel_scratch_len_batched(1), cfg.max_kernel_scratch_len());
            // sizing grows monotonically with the batch
            let mut prev = 0usize;
            for n in 1..=8 {
                let len = cfg.scratch_i8_len_batched(n);
                assert!(len > prev, "{}: batch {n} sized {len} <= {prev}", cfg.name);
                prev = len;
                assert_eq!(cfg.workspace_batched(n).i8_capacity(), len);
            }
            // a batch-n arena covers every smaller batch
            assert!(cfg.scratch_i8_len_batched(8) >= cfg.scratch_i8_len_batched(3));
        }
    }

    #[test]
    fn json_roundtrip() {
        for cfg in all() {
            let j = cfg.to_json().to_string_pretty();
            let back = CapsNetConfig::from_json(&JsonValue::parse(&j).unwrap()).unwrap();
            assert_eq!(back, cfg);
        }
    }

    #[test]
    fn param_counts_are_plausible() {
        // MNIST float model ≈ 1187.20 KB in the paper (Table 2). Our config
        // derives ~290 K params ≈ 1.13 MB float — same ballpark; the exact
        // figure depends on their unpublished aux parameters.
        let n = mnist().num_params();
        assert!((250_000..350_000).contains(&n), "mnist params = {n}");
    }

    #[test]
    fn shapes_propagate() {
        let cfg = cifar10();
        let d0 = cfg.conv_dims(0);
        assert_eq!((d0.in_h, d0.in_ch, d0.out_ch), (32, 3, 32));
        let d3 = cfg.conv_dims(3);
        assert_eq!((d3.in_h, d3.in_w), (13, 13));
        let p = cfg.pcap_dims();
        assert_eq!((p.conv.in_h, p.conv.in_ch), (6, 64));
        assert_eq!(p.total_caps(), 64);
    }
}
