//! Float-32 CapsNet reference engine.
//!
//! Mirrors the Python/JAX model (`python/compile/model.py`) exactly: same
//! layer order, same squash (Eq. 1), same dynamic routing (Algorithm 1).
//! Used for (a) Table-2 float-vs-int8 accuracy comparisons on the Rust side
//! and (b) cross-checking against the AOT-lowered HLO executed through PJRT.

use crate::formats::{Archive, JsonValue};
use crate::kernels::squash::squash_f32;
use crate::model::config::CapsNetConfig;
use anyhow::{Context, Result};
use std::path::Path;

/// A float CapsNet (weights as trained).
#[derive(Clone, Debug)]
pub struct FloatCapsNet {
    pub config: CapsNetConfig,
    /// Per conv layer: (weights `[out_ch, kh, kw, in_ch]`, bias).
    pub convs: Vec<(Vec<f32>, Vec<f32>)>,
    /// Primary capsule conv weights + bias.
    pub pcap: (Vec<f32>, Vec<f32>),
    /// Per capsule layer: weights `[out_caps, in_caps, out_dim, in_dim]`.
    pub caps: Vec<Vec<f32>>,
}

impl FloatCapsNet {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let a = Archive::load(path)?;
        Self::from_archive(&a)
    }

    pub fn from_archive(a: &Archive) -> Result<Self> {
        let cfg_bytes = a.req("config.json")?.as_u8()?;
        let config = CapsNetConfig::from_json(&JsonValue::parse(
            std::str::from_utf8(cfg_bytes).context("config.json utf8")?,
        )?)?;
        let mut convs = Vec::new();
        for i in 0..config.conv_layers.len() {
            convs.push((
                a.req(&format!("conv{i}.w"))?.as_f32()?.to_vec(),
                a.req(&format!("conv{i}.b"))?.as_f32()?.to_vec(),
            ));
        }
        let pcap = (a.req("pcap.w")?.as_f32()?.to_vec(), a.req("pcap.b")?.as_f32()?.to_vec());
        let mut caps = Vec::new();
        for i in 0..config.caps_layers.len() {
            caps.push(a.req(&format!("caps{i}.w"))?.as_f32()?.to_vec());
        }
        Ok(FloatCapsNet { config, convs, pcap, caps })
    }

    /// Forward pass; returns final capsule outputs `[classes × dim]`.
    pub fn forward(&self, input: &[f32]) -> Vec<f32> {
        assert_eq!(input.len(), self.config.input_len());
        let mut act = input.to_vec();
        for (i, (w, b)) in self.convs.iter().enumerate() {
            let d = self.config.conv_dims(i);
            act = conv2d_f32(&act, w, b, &d, true);
        }
        // primary capsules
        let pd = self.config.pcap_dims();
        let mut out = conv2d_f32(&act, &self.pcap.0, &self.pcap.1, &pd.conv, false);
        for r in 0..pd.total_caps() {
            squash_f32(&mut out[r * pd.cap_dim..(r + 1) * pd.cap_dim]);
        }
        act = out;
        // capsule layers with dynamic routing
        for (i, w) in self.caps.iter().enumerate() {
            let d = self.config.caps_dims(i);
            let routings = self.config.caps_layers[i].routings;
            act = capsule_layer_f32(&act, w, d.in_caps, d.in_dim, d.out_caps, d.out_dim, routings);
        }
        act
    }

    /// Predicted class = capsule with largest norm.
    pub fn classify(&self, caps_out: &[f32]) -> usize {
        let dim = self.config.caps_layers.last().map(|l| l.cap_dim).unwrap_or(1);
        let n = caps_out.len() / dim;
        (0..n)
            .max_by(|&a, &b| {
                let na: f32 = caps_out[a * dim..(a + 1) * dim].iter().map(|x| x * x).sum();
                let nb: f32 = caps_out[b * dim..(b + 1) * dim].iter().map(|x| x * x).sum();
                na.partial_cmp(&nb).unwrap()
            })
            .unwrap_or(0)
    }
}

/// HWC float conv (VALID/explicit pad), weights `[out_ch, kh, kw, in_ch]`.
pub fn conv2d_f32(
    input: &[f32],
    w: &[f32],
    bias: &[f32],
    d: &crate::kernels::conv::ConvDims,
    relu: bool,
) -> Vec<f32> {
    let (oh, ow) = (d.out_h(), d.out_w());
    let kkc = d.kkc();
    let mut out = vec![0f32; oh * ow * d.out_ch];
    for oy in 0..oh {
        for ox in 0..ow {
            for c in 0..d.out_ch {
                let mut sum = bias[c];
                let wrow = &w[c * kkc..(c + 1) * kkc];
                let mut idx = 0;
                for ky in 0..d.k_h {
                    let iy = (oy * d.stride + ky) as isize - d.pad as isize;
                    for kx in 0..d.k_w {
                        let ix = (ox * d.stride + kx) as isize - d.pad as isize;
                        if iy >= 0 && (iy as usize) < d.in_h && ix >= 0 && (ix as usize) < d.in_w {
                            let base = (iy as usize * d.in_w + ix as usize) * d.in_ch;
                            for ic in 0..d.in_ch {
                                sum += input[base + ic] * wrow[idx + ic];
                            }
                        }
                        idx += d.in_ch;
                    }
                }
                out[(oy * ow + ox) * d.out_ch + c] = if relu { sum.max(0.0) } else { sum };
            }
        }
    }
    out
}

/// Float dynamic routing (paper Algorithm 1).
pub fn capsule_layer_f32(
    u: &[f32],
    w: &[f32],
    in_caps: usize,
    in_dim: usize,
    out_caps: usize,
    out_dim: usize,
    routings: usize,
) -> Vec<f32> {
    assert_eq!(u.len(), in_caps * in_dim);
    assert_eq!(w.len(), out_caps * in_caps * out_dim * in_dim);
    // û[j, i, :] = W[j, i] · u[i]
    let mut uhat = vec![0f32; out_caps * in_caps * out_dim];
    for j in 0..out_caps {
        for i in 0..in_caps {
            let wij = &w[(j * in_caps + i) * out_dim * in_dim..];
            for e in 0..out_dim {
                let mut s = 0f32;
                for k in 0..in_dim {
                    s += wij[e * in_dim + k] * u[i * in_dim + k];
                }
                uhat[(j * in_caps + i) * out_dim + e] = s;
            }
        }
    }
    let mut b = vec![0f32; in_caps * out_caps];
    let mut v = vec![0f32; out_caps * out_dim];
    for r in 0..routings {
        // c = softmax over out_caps for each in_cap
        let mut c = vec![0f32; in_caps * out_caps];
        for i in 0..in_caps {
            let row = &b[i * out_caps..(i + 1) * out_caps];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = row.iter().map(|&x| (x - max).exp()).collect();
            let sum: f32 = exps.iter().sum();
            for j in 0..out_caps {
                c[i * out_caps + j] = exps[j] / sum;
            }
        }
        // s_j = Σ_i c_ij û_ij ; v_j = squash(s_j)
        for j in 0..out_caps {
            let vj = &mut v[j * out_dim..(j + 1) * out_dim];
            vj.fill(0.0);
            for i in 0..in_caps {
                let cij = c[i * out_caps + j];
                let uh = &uhat[(j * in_caps + i) * out_dim..(j * in_caps + i + 1) * out_dim];
                for e in 0..out_dim {
                    vj[e] += cij * uh[e];
                }
            }
            squash_f32(vj);
        }
        // b_ij += û_ij · v_j
        if r + 1 < routings {
            for j in 0..out_caps {
                let vj = &v[j * out_dim..(j + 1) * out_dim];
                for i in 0..in_caps {
                    let uh = &uhat[(j * in_caps + i) * out_dim..(j * in_caps + i + 1) * out_dim];
                    let agr: f32 = uh.iter().zip(vj.iter()).map(|(a, b)| a * b).sum();
                    b[i * out_caps + j] += agr;
                }
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::conv::ConvDims;
    use crate::testing::prop::{Prop, XorShift};

    #[test]
    fn conv_identity() {
        let d = ConvDims { in_h: 2, in_w: 2, in_ch: 1, out_ch: 1, k_h: 1, k_w: 1, stride: 1, pad: 0 };
        let out = conv2d_f32(&[1.0, -2.0, 3.0, -4.0], &[1.0], &[0.0], &d, false);
        assert_eq!(out, vec![1.0, -2.0, 3.0, -4.0]);
        let out = conv2d_f32(&[1.0, -2.0, 3.0, -4.0], &[1.0], &[0.5], &d, true);
        assert_eq!(out, vec![1.5, 0.0, 3.5, 0.0]);
    }

    #[test]
    fn routing_coupling_sums_preserved() {
        // After routing, output capsule norms must all be <= 1 (squashed).
        Prop::new("float routing squashes", 100).run(|rng: &mut XorShift| {
            let (ic, id, oc, od) = (rng.range(2, 10), rng.range(2, 5), rng.range(2, 5), rng.range(2, 5));
            let u = rng.f32_vec(ic * id, 1.0);
            let w = rng.f32_vec(oc * ic * od * id, 1.0);
            let v = capsule_layer_f32(&u, &w, ic, id, oc, od, 3);
            for j in 0..oc {
                let norm: f32 = v[j * od..(j + 1) * od].iter().map(|x| x * x).sum::<f32>().sqrt();
                assert!(norm <= 1.0 + 1e-5, "cap {j} norm {norm}");
            }
        });
    }

    #[test]
    fn squash_f32_known_values() {
        // |s| = 1 → |v| = 0.5
        let mut v = vec![1.0f32, 0.0];
        squash_f32(&mut v);
        assert!((v[0] - 0.5).abs() < 1e-6 && v[1] == 0.0, "{v:?}");
    }
}
