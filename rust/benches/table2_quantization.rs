//! Regenerates paper Table 2 (quantization: memory footprint + accuracy)
//! from the shipped artifacts, through the *Rust* engines.
//!
//! The Python framework writes its own Table-2 report during
//! `make artifacts` (artifacts/reports/table2.json); this bench re-measures
//! the int-8 column natively and prints both next to the paper's values.

use capsnet_edge::dataset::EvalSet;
use capsnet_edge::isa::NullMeter;
use capsnet_edge::model::{configs, ArmConv, FloatCapsNet, QuantizedCapsNet};
use std::path::Path;

/// Paper Table 2 reference rows: (dataset, float KB, int8 KB, saving %,
/// float acc %, int8 acc %, loss pp).
const PAPER: &[(&str, f64, f64, f64, f64, f64, f64)] = &[
    ("mnist", 1187.20, 296.82, 74.99, 99.01, 98.83, 0.18),
    ("smallnorb", 1182.34, 295.61, 74.99, 92.56, 92.49, 0.07),
    ("cifar10", 461.19, 115.33, 74.99, 78.54, 78.38, 0.16),
];

fn main() {
    println!("── Table 2 — quantization framework evaluation ──");
    println!(
        "{:<10} {:>12} {:>12} {:>9} {:>11} {:>11} {:>9}",
        "dataset", "float KB", "int8 KB", "saving%", "float acc%", "int8 acc%", "loss pp"
    );
    for &(name, p_fkb, p_ikb, p_sv, p_fa, p_ia, p_loss) in PAPER {
        let cnq = format!("artifacts/models/{name}.cnq");
        let f32p = format!("artifacts/models/{name}.f32.npt");
        let evalp = format!("artifacts/data/{name}_eval.npt");
        if !Path::new(&cnq).exists() {
            println!("{name:<10} SKIP (run `make artifacts`)");
            continue;
        }
        let qnet = QuantizedCapsNet::load(&cnq).unwrap();
        let fnet = FloatCapsNet::load(&f32p).unwrap();
        let eval = EvalSet::load(&evalp).unwrap();
        let cfg = configs::by_name(name).unwrap();
        let n = 256.min(eval.len());
        let mut f_ok = 0usize;
        let mut q_ok = 0usize;
        for i in 0..n {
            let img = eval.image(i);
            if fnet.classify(&fnet.forward(img)) == eval.labels[i] as usize {
                f_ok += 1;
            }
            let q = qnet.quantize_input(img);
            let out = qnet.forward_arm(&q, ArmConv::FastWithFallback, &mut NullMeter);
            if qnet.classify(&out) == eval.labels[i] as usize {
                q_ok += 1;
            }
        }
        let fkb = cfg.float_bytes() as f64 / 1024.0;
        let ikb = cfg.int8_bytes() as f64 / 1024.0;
        let saving = 100.0 * (1.0 - ikb / fkb);
        let fa = 100.0 * f_ok as f64 / n as f64;
        let ia = 100.0 * q_ok as f64 / n as f64;
        println!(
            "{name:<10} {fkb:>12.2} {ikb:>12.2} {saving:>9.2} {fa:>11.2} {ia:>11.2} {:>9.2}",
            fa - ia
        );
        println!(
            "{:<10} {p_fkb:>12.2} {p_ikb:>12.2} {p_sv:>9.2} {p_fa:>11.2} {p_ia:>11.2} {p_loss:>9.2}",
            "  (paper)"
        );
        // Shape assertions: ~75% saving; |loss| below 1 pp (paper: ≤ 0.18).
        assert!((74.5..75.1).contains(&saving), "{name}: saving {saving}");
        assert!((fa - ia).abs() <= 1.0, "{name}: loss {}pp", fa - ia);
    }
}
