//! L3 §Perf: coordinator dispatch overhead, routing throughput, and the
//! batch-amortization win of the pooled serving path (EXPERIMENTS.md §Perf
//! target: ≥ 10⁵ routed requests/s with ~µs-scale dispatch overhead;
//! ISSUE 2 target: batch-8 pooled RPS ≥ 1.5× batch-1 on the paper's MNIST
//! CapsNet).
//!
//! Section 1 uses `execute = false` so the measurement isolates routing +
//! virtual scheduling from the inference engine. Section 2 runs **real**
//! int-8 inference through `Fleet::serve_pooled` at batch 1/4/8: one fixed
//! worker pool, each worker with a resident batch-capacity arena, the
//! batched kernels streaming each weight set once per batch.

use capsnet_edge::bench_support::{bench_wall, write_bench_json};
use capsnet_edge::coordinator::{
    BatchPolicy, Fault, FaultPlan, Fleet, Request, RouterPolicy, ServeConfig, TraceKind,
    TraceSpec,
};
use capsnet_edge::formats::JsonValue;
use capsnet_edge::isa::Board;
use capsnet_edge::model::{configs, QuantizedCapsNet};
use capsnet_edge::testing::prop::XorShift;
use std::hint::black_box;
use std::sync::Arc;

fn main() {
    let model = Arc::new(QuantizedCapsNet::random(configs::cifar10(), 1));
    let n = 50_000usize;
    let requests: Vec<Request> = (0..n)
        .map(|i| Request {
            id: i as u64,
            arrival_ms: i as f64 * 0.01,
            input_q: Vec::new(), // latency-only simulation reads no input
            label: None,
        })
        .collect();

    println!("── Coordinator dispatch micro-benchmark ({n} requests, 4 devices) ──");
    let mut policy_rows = Vec::new();
    for policy in RouterPolicy::all() {
        let us = bench_wall(1, 5, || {
            let mut fleet = Fleet::new(policy);
            for b in Board::all() {
                fleet.add_device(b, model.clone()).unwrap();
            }
            fleet.execute = false;
            for d in fleet.devices.iter_mut() {
                d.queue_limit = usize::MAX;
            }
            black_box(fleet.simulate(black_box(&requests)).unwrap());
        });
        let per_req_us = us / n as f64;
        let rps = 1e6 / per_req_us;
        println!(
            "{:<16}: {:>7.3} µs/request dispatch  ->  {:>10.0} routed req/s  {}",
            policy.name(),
            per_req_us,
            rps,
            if rps >= 1e5 { "PASS(>=1e5)" } else { "MISS" }
        );
        policy_rows.push((
            policy.name(),
            JsonValue::obj(vec![
                ("us_per_request", JsonValue::num(per_req_us)),
                ("routed_req_per_s", JsonValue::num(rps)),
                ("pass_1e5_rps", JsonValue::Bool(rps >= 1e5)),
            ]),
        ));
    }

    // ── Pooled batch serving: real inference, MNIST config (the paper's
    // headline model), RPS at batch 1/4/8 ──────────────────────────────────
    let mnist = Arc::new(QuantizedCapsNet::random(configs::mnist(), 2));
    let mut fleet = Fleet::new(RouterPolicy::RoundRobin);
    for b in Board::all() {
        fleet.add_device(b, mnist.clone()).unwrap();
    }
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(8);
    let n_serve = 256usize;
    let mut rng = XorShift::new(3);
    let serve_requests: Vec<Request> = (0..n_serve)
        .map(|i| Request {
            id: i as u64,
            arrival_ms: 0.0, // one burst → batchify closes full batches
            input_q: rng.i8_vec(mnist.config.input_len()),
            label: None,
        })
        .collect();
    println!(
        "\n── Pooled serving, real int-8 MNIST inference ({n_serve} requests, {workers} workers) ──"
    );
    let mut batch_rows = Vec::new();
    let mut rps_at = [0f64; 3];
    for (bi, &batch) in [1usize, 4, 8].iter().enumerate() {
        let policy = BatchPolicy::new(1e9, batch);
        // median-of-5 wall-clock runs for a stable RPS
        let us = bench_wall(1, 5, || {
            black_box(fleet.serve_pooled(black_box(&serve_requests), policy, workers).unwrap());
        });
        let rps = n_serve as f64 / (us / 1e6);
        rps_at[bi] = rps;
        println!("batch {batch}: {:>10.0} req/s  ({:.1} µs/request)", rps, us / n_serve as f64);
        batch_rows.push((
            ["batch_1", "batch_4", "batch_8"][bi],
            JsonValue::obj(vec![
                ("rps", JsonValue::num(rps)),
                ("us_per_request", JsonValue::num(us / n_serve as f64)),
            ]),
        ));
    }
    let amortization = rps_at[2] / rps_at[0];
    let pass = amortization >= 1.5;
    println!(
        "batch-8 / batch-1 amortization: {:.2}x {}",
        amortization,
        if pass { "PASS(>=1.5x)" } else { "MISS" }
    );

    // ── RISC-V pooled serving: an all-GAP-8 fleet runs the riscv batched
    // kernel stack (per-worker resident ClusterRun) at host speed ─────────
    let mut rv_fleet = Fleet::new(RouterPolicy::RoundRobin);
    for _ in 0..2 {
        rv_fleet.add_device(Board::gapuino(), mnist.clone()).unwrap();
    }
    println!("\n── RISC-V pooled serving, real int-8 MNIST inference ({n_serve} requests) ──");
    let mut rv_rows = Vec::new();
    for (bi, &batch) in [1usize, 8].iter().enumerate() {
        let policy = BatchPolicy::new(1e9, batch);
        let us = bench_wall(1, 5, || {
            black_box(rv_fleet.serve_pooled(black_box(&serve_requests), policy, workers).unwrap());
        });
        let rps = n_serve as f64 / (us / 1e6);
        println!("batch {batch}: {:>10.0} req/s  ({:.1} µs/request)", rps, us / n_serve as f64);
        rv_rows.push((
            ["batch_1", "batch_8"][bi],
            JsonValue::obj(vec![
                ("rps", JsonValue::num(rps)),
                ("us_per_request", JsonValue::num(us / n_serve as f64)),
            ]),
        ));
    }

    // ── Degraded-fleet serving: 4 identical boards, one dies before
    // serving anything. The control plane re-dispatches the lost work, so
    // throughput should degrade roughly like capacity (≥ 0.6× healthy with
    // 1-of-4 dead), not collapse — the gate for recovery overhead ─────────
    let mut deg_fleet = Fleet::new(RouterPolicy::RoundRobin);
    for _ in 0..4 {
        deg_fleet.add_device(Board::stm32h755(), mnist.clone()).unwrap();
    }
    let deg_policy = BatchPolicy::new(1e9, 4);
    println!("\n── Degraded-fleet pooled serving (4 devices, 1 dead, {n_serve} requests) ──");
    let healthy_us = bench_wall(1, 5, || {
        black_box(deg_fleet.serve_pooled(black_box(&serve_requests), deg_policy, workers).unwrap());
    });
    let healthy_rps = n_serve as f64 / (healthy_us / 1e6);
    let cfg = ServeConfig {
        faults: FaultPlan { faults: vec![Fault::Die { device: 0, after_requests: 0 }] },
        ..ServeConfig::default()
    };
    let degraded_us = bench_wall(1, 5, || {
        black_box(
            deg_fleet
                .serve_pooled_with(black_box(&serve_requests), deg_policy, workers, &cfg)
                .unwrap(),
        );
    });
    let degraded_rps = n_serve as f64 / (degraded_us / 1e6);
    let deg_ratio = degraded_rps / healthy_rps;
    let deg_pass = deg_ratio >= 0.6;
    println!("healthy : {healthy_rps:>10.0} req/s");
    println!("1/4 dead: {degraded_rps:>10.0} req/s");
    println!(
        "degraded / healthy: {:.2}x {}",
        deg_ratio,
        if deg_pass { "PASS(>=0.6x)" } else { "MISS" }
    );

    // ── Scenario goodput: SLO-aware serving of a deterministic bursty
    // trace at 2x fleet capacity — healthy, then with one board dead at
    // request zero. The virtual clock makes both runs deterministic (one
    // rep suffices); the gated metric is goodput (in-SLO completions per
    // virtual second) as a fraction of raw fleet capacity ────────────────
    let capacity_rps: f64 = deg_fleet.devices.iter().map(|d| 1e3 / d.inference_ms).sum();
    let est_ms =
        deg_fleet.devices.iter().map(|d| d.inference_ms).fold(f64::INFINITY, f64::min);
    let slo_ms = 8.0 * est_ms;
    let trace = TraceSpec { kind: TraceKind::Bursty, rps: 2.0 * capacity_rps, seed: 11 };
    let arrivals = trace.arrivals(n_serve);
    let burst_requests: Vec<Request> = serve_requests
        .iter()
        .zip(&arrivals)
        .map(|(r, &t)| Request { arrival_ms: t, ..r.clone() })
        .collect();
    println!(
        "\n── Scenario goodput: bursty 2x-capacity trace ({:.0} req/s, slo {slo_ms:.2} ms) ──",
        trace.rps
    );
    let mut scenario_rows = Vec::new();
    for (name, faults) in [
        ("bursty_overload", FaultPlan::none()),
        ("degraded_burst", FaultPlan { faults: vec![Fault::Die { device: 0, after_requests: 0 }] }),
    ] {
        let cfg = ServeConfig { slo_ms: Some(slo_ms), faults, ..ServeConfig::default() };
        let report =
            deg_fleet.serve_pooled_with(&burst_requests, deg_policy, workers, &cfg).unwrap();
        let ratio = report.goodput_rps() / capacity_rps;
        println!(
            "{name:<16}: goodput {:>8.1} req/s virtual  ({:.2}x capacity, {} rejected)",
            report.goodput_rps(),
            ratio,
            report.rejections.len(),
        );
        let row = JsonValue::obj(vec![("goodput_ratio_vs_capacity", JsonValue::num(ratio))]);
        scenario_rows.push((name, row));
    }

    write_bench_json(
        "BENCH_coordinator.json",
        &JsonValue::obj(vec![
            ("bench", JsonValue::str("coordinator")),
            ("requests", JsonValue::int(n as i64)),
            ("devices", JsonValue::int(Board::all().len() as i64)),
            ("policies", JsonValue::obj(policy_rows)),
            (
                "pooled_serving",
                JsonValue::obj(
                    vec![
                        ("model", JsonValue::str("mnist")),
                        ("workers", JsonValue::int(workers as i64)),
                        ("requests", JsonValue::int(n_serve as i64)),
                    ]
                    .into_iter()
                    .chain(batch_rows)
                    .chain(vec![
                        ("batch8_over_batch1", JsonValue::num(amortization)),
                        ("pass_batch8_1p5x", JsonValue::Bool(pass)),
                    ])
                    .collect(),
                ),
            ),
            (
                "riscv_pooled_serving",
                JsonValue::obj(
                    vec![("model", JsonValue::str("mnist")), ("devices", JsonValue::int(2))]
                        .into_iter()
                        .chain(rv_rows)
                        .collect(),
                ),
            ),
            (
                "scenario_serving",
                JsonValue::obj(
                    vec![
                        ("trace", JsonValue::str("bursty")),
                        ("slo_over_min_inference", JsonValue::int(8)),
                    ]
                    .into_iter()
                    .chain(scenario_rows)
                    .collect(),
                ),
            ),
            (
                "degraded_serving",
                JsonValue::obj(vec![
                    ("devices", JsonValue::int(4)),
                    ("dead", JsonValue::int(1)),
                    ("healthy_rps", JsonValue::num(healthy_rps)),
                    ("degraded_rps", JsonValue::num(degraded_rps)),
                    ("rps_ratio_vs_healthy", JsonValue::num(deg_ratio)),
                    ("pass_0p6x", JsonValue::Bool(deg_pass)),
                ]),
            ),
        ]),
    );
}
