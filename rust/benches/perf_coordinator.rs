//! L3 §Perf: coordinator dispatch overhead and routing throughput
//! (EXPERIMENTS.md §Perf target: ≥ 10⁵ routed requests/s with ~µs-scale
//! dispatch overhead).
//!
//! Uses `execute = false` so the measurement isolates routing + virtual
//! scheduling from the inference engine itself.

use capsnet_edge::bench_support::{bench_wall, write_bench_json};
use capsnet_edge::coordinator::{Fleet, Request, RouterPolicy};
use capsnet_edge::formats::JsonValue;
use capsnet_edge::isa::Board;
use capsnet_edge::model::{configs, QuantizedCapsNet};
use std::hint::black_box;
use std::sync::Arc;

fn main() {
    let model = Arc::new(QuantizedCapsNet::random(configs::cifar10(), 1));
    let n = 50_000usize;
    let requests: Vec<Request> = (0..n)
        .map(|i| Request {
            id: i as u64,
            arrival_ms: i as f64 * 0.01,
            input_q: Vec::new(), // latency-only simulation reads no input
            label: None,
        })
        .collect();

    println!("── Coordinator dispatch micro-benchmark ({n} requests, 4 devices) ──");
    let mut policy_rows = Vec::new();
    for policy in RouterPolicy::all() {
        let us = bench_wall(1, 5, || {
            let mut fleet = Fleet::new(policy);
            for b in Board::all() {
                fleet.add_device(b, model.clone()).unwrap();
            }
            fleet.execute = false;
            for d in fleet.devices.iter_mut() {
                d.queue_limit = usize::MAX;
            }
            black_box(fleet.simulate(black_box(&requests)));
        });
        let per_req_us = us / n as f64;
        let rps = 1e6 / per_req_us;
        println!(
            "{:<16}: {:>7.3} µs/request dispatch  ->  {:>10.0} routed req/s  {}",
            policy.name(),
            per_req_us,
            rps,
            if rps >= 1e5 { "PASS(>=1e5)" } else { "MISS" }
        );
        policy_rows.push((
            policy.name(),
            JsonValue::obj(vec![
                ("us_per_request", JsonValue::num(per_req_us)),
                ("routed_req_per_s", JsonValue::num(rps)),
                ("pass_1e5_rps", JsonValue::Bool(rps >= 1e5)),
            ]),
        ));
    }
    write_bench_json(
        "BENCH_coordinator.json",
        &JsonValue::obj(vec![
            ("bench", JsonValue::str("coordinator")),
            ("requests", JsonValue::int(n as i64)),
            ("devices", JsonValue::int(Board::all().len() as i64)),
            ("policies", JsonValue::obj(policy_rows)),
        ]),
    );
}
