//! Regenerates paper Table6 via the shared harness (see
//! `bench_support::table6` for workload + paper reference values), and
//! wall-clock-times the host-side execution of the same workload.

use capsnet_edge::bench_support::{self, bench_wall};

fn main() {
    let t = bench_support::table6();
    println!("{}", t.render());
    println!("mean |rel err| vs paper: {:.1}%", 100.0 * t.mean_abs_rel_error());
    let host_us = bench_wall(2, 5, || {
        std::hint::black_box(bench_support::table6());
    });
    println!("host wall time per full-table evaluation: {:.0} µs", host_us);
}
