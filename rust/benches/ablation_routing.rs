//! Ablation: dynamic-routing iteration count vs capsule-layer latency and
//! classification agreement (DESIGN.md §5 ablations).
//!
//! The paper fixes routings = 3 (Table 1); this sweep shows what that
//! choice costs and whether fewer iterations change predictions — the
//! question behind the routing-skipping optimizations of Zhang et al. 2021
//! and Park et al. 2020 discussed in the paper's related work.

use capsnet_edge::dataset::EvalSet;
use capsnet_edge::isa::{Board, CycleCounter, NullMeter};
use capsnet_edge::kernels::capsule::{capsule_layer_q7_arm, CapsuleShifts};
use capsnet_edge::model::{ArmConv, QuantizedCapsNet};
use std::path::Path;

fn main() {
    let cnq = "artifacts/models/mnist.cnq";
    if !Path::new(cnq).exists() {
        println!("SKIP (run `make artifacts`)");
        return;
    }
    let net = QuantizedCapsNet::load(cnq).unwrap();
    let eval = EvalSet::load("artifacts/data/mnist_eval.npt").unwrap();
    let d = net.config.caps_dims(0);
    let board = Board::stm32h755();

    // Baseline predictions at the shipped 3 routings.
    let n = 64.min(eval.len());
    let baseline: Vec<usize> = (0..n)
        .map(|i| {
            let q = net.quantize_input(eval.image(i));
            let out = net.forward_arm(&q, ArmConv::Basic, &mut NullMeter);
            net.classify(&out)
        })
        .collect();

    println!("── Ablation: routing iterations (MNIST capsule layer, Cortex-M7) ──");
    println!(
        "{:>9} {:>14} {:>12} {:>22}",
        "routings", "layer cycles", "layer ms", "agreement vs r=3 (%)"
    );
    for routings in 1..=5 {
        // Layer-only latency with uniform shifts of the right length.
        let shifts = CapsuleShifts {
            inputs_hat: net.caps[0].shifts.inputs_hat,
            caps_out: vec![net.caps[0].shifts.caps_out[0]; routings],
            squash_in_qn: vec![net.caps[0].shifts.squash_in_qn[0]; routings],
            agreement: vec![
                *net.caps[0].shifts.agreement.first().unwrap_or(&12);
                routings.saturating_sub(1)
            ],
            logit_acc: vec![0; routings.saturating_sub(1)],
        };
        let mut cc = CycleCounter::new(board.cost_model());
        let mut u = vec![0i8; d.input_len()];
        // representative input: real capsule activations from sample 0
        let q = net.quantize_input(eval.image(0));
        let pd = net.config.pcap_dims();
        {
            use capsnet_edge::kernels::pcap::pcap_q7_basic;
            let mut conv_out = vec![0i8; net.config.conv_dims(0).out_len()];
            use capsnet_edge::kernels::conv::arm_convolve_hwc_q7_basic;
            let cd = net.config.conv_dims(0);
            arm_convolve_hwc_q7_basic(
                &q, &net.convs[0].w, &net.convs[0].b, &cd,
                net.convs[0].bias_shift, net.convs[0].out_shift, true, &mut conv_out,
                &mut NullMeter,
            );
            let mut pout = vec![0i8; pd.out_len()];
            pcap_q7_basic(&conv_out, &net.pcap.w, &net.pcap.b, &pd, net.pcap.shifts, &mut pout, &mut NullMeter);
            u.copy_from_slice(&pout);
        }
        let mut out = vec![0i8; d.output_len()];
        capsule_layer_q7_arm(&u, &net.caps[0].w, &d, routings, &shifts, &mut out, &mut cc);

        // Classification agreement with the shipped 3-routing model.
        let mut agree = 0;
        for i in 0..n {
            let q = net.quantize_input(eval.image(i));
            let mut var = net.clone();
            var.caps[0].shifts = shifts.clone();
            var.config.caps_layers[0].routings = routings;
            let o = var.forward_arm(&q, ArmConv::Basic, &mut NullMeter);
            if var.classify(&o) == baseline[i] {
                agree += 1;
            }
        }
        println!(
            "{routings:>9} {:>14} {:>12.2} {:>22.1}",
            cc.cycles(),
            board.cycles_to_ms(cc.cycles()),
            100.0 * agree as f64 / n as f64
        );
    }
    println!("\n(routing cost is ~linear in iterations; prediction agreement quantifies\n how much the extra iterations actually change the classification)");
}
