//! L3 §Perf: plan-driven vs pinned-strategy execution (ISSUE 3 target:
//! planned execution ≥ pinned-`HoWo` execution on the Table 6 workloads).
//!
//! For each reference CapsNet on the GAP-8 board, meters one full forward
//! pass with (a) the pre-planner pinned `HoWo` strategy and (b) the
//! per-layer schedule the deployment planner derives from the calibrated
//! cycle model. The planner enumerates `HoWo` among its candidates, so the
//! planned schedule can only match or beat the pinned one — a violation
//! aborts the bench (and the CI perf job with it). Results land in
//! `BENCH_plan.json`.

use capsnet_edge::bench_support::write_bench_json;
use capsnet_edge::formats::JsonValue;
use capsnet_edge::isa::{Board, ClusterRun, CostModel};
use capsnet_edge::kernels::conv::PulpConvStrategy;
use capsnet_edge::model::{configs, QuantizedCapsNet};
use capsnet_edge::plan::{plan_deployment, PlanOptions};
use capsnet_edge::testing::prop::XorShift;

fn main() {
    let board = Board::gapuino();
    let mut rows: Vec<(String, JsonValue)> = Vec::new();
    println!("── Plan-driven vs pinned-HoWo riscv execution (GAP-8 x8) ──");
    for cfg in configs::all() {
        let net = QuantizedCapsNet::random(cfg.clone(), 42);
        let mut rng = XorShift::new(7);
        let input = rng.i8_vec(net.config.input_len());
        let mut ws = net.config.workspace();
        let mut out = vec![0i8; net.config.output_len()];

        let mut pinned_run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
        net.forward_riscv_into(&input, PulpConvStrategy::HoWo, &mut ws, &mut out, &mut pinned_run);
        let pinned = pinned_run.cycles();

        let plan = plan_deployment(&cfg, &board, &PlanOptions::default());
        let schedule = plan.riscv_schedule().expect("gap8 plan resolves a riscv schedule");
        let mut planned_run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
        net.forward_riscv_scheduled_into(&input, &schedule, &mut ws, &mut out, &mut planned_run);
        let planned = planned_run.cycles();

        let speedup = pinned as f64 / planned as f64;
        let strategies: Vec<&str> =
            schedule.iter().map(|s| s.name()).collect();
        println!(
            "{:<10} pinned {:>10.2}M cyc ({:.2} ms) | planned {:>10.2}M cyc ({:.2} ms) | {:.3}x  [{}]",
            cfg.name,
            pinned as f64 / 1e6,
            board.cycles_to_ms(pinned),
            planned as f64 / 1e6,
            board.cycles_to_ms(planned),
            speedup,
            strategies.join(",")
        );
        assert!(
            planned <= pinned,
            "{}: planned execution ({planned} cycles) lost to pinned HoWo ({pinned})",
            cfg.name
        );
        rows.push((
            cfg.name.clone(),
            JsonValue::obj(vec![
                ("pinned_howo_cycles", JsonValue::int(pinned as i64)),
                ("planned_cycles", JsonValue::int(planned as i64)),
                ("speedup", JsonValue::num(speedup)),
                (
                    "schedule",
                    JsonValue::Array(strategies.iter().map(|s| JsonValue::str(s)).collect()),
                ),
            ]),
        ));
    }
    println!("planned <= pinned on every workload: PASS");
    write_bench_json(
        "BENCH_plan.json",
        &JsonValue::obj(
            vec![("bench", JsonValue::str("plan")), ("board", JsonValue::str(board.name))]
                .into_iter()
                .chain(rows.iter().map(|(k, v)| (k.as_str(), v.clone())))
                .collect(),
        ),
    );
}
