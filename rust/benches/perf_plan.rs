//! L3 §Perf: plan-driven vs pinned-strategy execution (ISSUE 3 target:
//! planned ≥ pinned-`HoWo`; ISSUE 4 target: mixed-split planned ≤
//! uniform-split planned on the Table 6/8 workloads).
//!
//! For each reference CapsNet on the GAP-8 board, meters one full forward
//! pass with (a) the pre-planner pinned `HoWo` full-cluster strategy,
//! (b) the uniform-split planned schedule (per-layer strategy argmin, every
//! layer on the full cluster — the pre-v2 planner), and (c) the mixed-split
//! planned schedule (argmin over strategies × per-layer core splits, each
//! layer its own fork/join section). `HoWo`×8 is in every candidate table
//! and the uniform candidates are a subset of the mixed ones, so the chain
//! mixed ≤ uniform ≤ pinned must hold — a violation aborts the bench (and
//! the CI perf job with it). Results land in `BENCH_plan.json`.

use capsnet_edge::bench_support::write_bench_json;
use capsnet_edge::exec::{run_program, Program, PulpBackend};
use capsnet_edge::formats::JsonValue;
use capsnet_edge::isa::{Board, ClusterRun, CostModel};
use capsnet_edge::kernels::conv::PulpConvStrategy;
use capsnet_edge::model::{configs, QuantizedCapsNet, RiscvSchedule};
use capsnet_edge::plan::{plan_deployment, PlanOptions};
use capsnet_edge::testing::prop::XorShift;

/// Meter one full forward under `schedule` through the execution engine:
/// lower once, interpret once (the serving shape — plan-driven devices hold
/// exactly such a program).
fn metered_cycles(net: &QuantizedCapsNet, input: &[i8], schedule: &RiscvSchedule) -> u64 {
    let prog = Program::lower_riscv(net, schedule, 1);
    let mut ws = net.config.workspace();
    let mut out = vec![0i8; net.config.output_len()];
    let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
    run_program(net, &prog, input, &mut ws, &mut out, &mut PulpBackend::new(&mut run));
    run.cycles()
}

fn schedule_names(s: &RiscvSchedule) -> Vec<String> {
    s.conv
        .iter()
        .map(|l| format!("{}x{}", l.strategy.name(), l.cores))
        .chain(s.caps.iter().map(|c| format!("routingx{c}")))
        .collect()
}

fn main() {
    let board = Board::gapuino();
    let mut rows: Vec<(String, JsonValue)> = Vec::new();
    println!("── Plan-driven vs pinned-HoWo riscv execution (GAP-8 x8) ──");
    for cfg in configs::all() {
        let net = QuantizedCapsNet::random(cfg.clone(), 42);
        let mut rng = XorShift::new(7);
        let input = rng.i8_vec(net.config.input_len());

        let mut ws = net.config.workspace();
        let mut out = vec![0i8; net.config.output_len()];
        let pinned_prog = Program::lower_riscv_uniform(&net, PulpConvStrategy::HoWo, 8, 1);
        let mut pinned_run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
        run_program(
            &net,
            &pinned_prog,
            &input,
            &mut ws,
            &mut out,
            &mut PulpBackend::new(&mut pinned_run),
        );
        let pinned = pinned_run.cycles();

        let uniform_plan = plan_deployment(
            &cfg,
            &board,
            &PlanOptions { mixed_splits: false, ..PlanOptions::default() },
        );
        let uniform_sched = uniform_plan.riscv_schedule().expect("gap8 uniform schedule");
        let uniform = metered_cycles(&net, &input, &uniform_sched);

        let mixed_plan = plan_deployment(&cfg, &board, &PlanOptions::default());
        let mixed_sched = mixed_plan.riscv_schedule().expect("gap8 mixed schedule");
        let mixed = metered_cycles(&net, &input, &mixed_sched);

        // Predicted ordering is exact by construction (the uniform
        // candidate set is a subset of the mixed one) — this can never
        // fail and anchors the metered checks below.
        assert!(
            mixed_plan.predicted_cycles <= uniform_plan.predicted_cycles,
            "{}: mixed argmin predicted above the uniform argmin",
            cfg.name
        );

        let speedup = pinned as f64 / mixed as f64;
        let strategies = schedule_names(&mixed_sched);
        println!(
            "{:<10} pinned {:>10.2}M cyc ({:.2} ms) | uniform-planned {:>10.2}M | \
             mixed-planned {:>10.2}M ({:.2} ms) | {:.3}x  [{}]",
            cfg.name,
            pinned as f64 / 1e6,
            board.cycles_to_ms(pinned),
            uniform as f64 / 1e6,
            mixed as f64 / 1e6,
            board.cycles_to_ms(mixed),
            speedup,
            strategies.join(",")
        );
        assert!(
            uniform <= pinned,
            "{}: uniform-planned execution ({uniform} cycles) lost to pinned HoWo ({pinned})",
            cfg.name
        );
        // Metered ordering on live data. Inputs and weights are fixed
        // seeds, so this is deterministic — never flaky. On the reference
        // nets every layer is large enough to amortize the full-cluster
        // fork/join, so the mixed and uniform schedules coincide and this
        // holds with equality; if a future config lands in the near-tie
        // regime where the planner's zero-operand squash/softmax pricing
        // mis-ranks a split on live data, this gate fails loudly — that is
        // a planner-mispricing signal to act on, not noise to tolerate.
        assert!(
            mixed <= uniform,
            "{}: mixed-split planned execution ({mixed} cycles) lost to uniform-split ({uniform})",
            cfg.name
        );
        rows.push((
            cfg.name.clone(),
            JsonValue::obj(vec![
                ("pinned_howo_cycles", JsonValue::int(pinned as i64)),
                ("uniform_planned_cycles", JsonValue::int(uniform as i64)),
                ("planned_cycles", JsonValue::int(mixed as i64)),
                ("speedup", JsonValue::num(speedup)),
                (
                    "schedule",
                    JsonValue::Array(
                        strategies.iter().map(|s| JsonValue::str(s)).collect(),
                    ),
                ),
            ]),
        ));
    }
    println!("mixed <= uniform <= pinned on every workload: PASS");
    write_bench_json(
        "BENCH_plan.json",
        &JsonValue::obj(
            vec![("bench", JsonValue::str("plan")), ("board", JsonValue::str(board.name))]
                .into_iter()
                .chain(rows.iter().map(|(k, v)| (k.as_str(), v.clone())))
                .collect(),
        ),
    );
}
