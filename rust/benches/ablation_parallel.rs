//! Ablation: PULP parallelization strategy × feature-map shape × core count
//! (DESIGN.md §5 ablations; extends paper Table 6's strategy comparison
//! with a full core sweep 1/2/4/8).

use capsnet_edge::bench_support::pcap_workloads;
use capsnet_edge::isa::{Board, ClusterRun, CostModel};
use capsnet_edge::kernels::conv::PulpConvStrategy;
use capsnet_edge::kernels::pcap::{pcap_q7_pulp, PcapShifts};
use capsnet_edge::kernels::squash::SquashParams;
use capsnet_edge::testing::prop::XorShift;

fn main() {
    let board = Board::gapuino();
    println!("── Ablation: parallelization strategy × cores (primary capsule) ──\n");
    for (label, d) in pcap_workloads() {
        let mut rng = XorShift::new(0xACE);
        let input = rng.i8_vec(d.conv.in_len());
        let w = rng.i8_vec(d.conv.weight_len());
        let bias = rng.i8_vec(d.conv.out_ch);
        let shifts =
            PcapShifts { bias_shift: 0, out_shift: 7, squash: SquashParams::q7_out(5) };
        println!("{label} (out grid {}x{}, {} ch):", d.conv.out_h(), d.conv.out_w(), d.conv.out_ch);
        println!("{:>14} {:>10} {:>10} {:>10} {:>10}", "strategy", "x1", "x2", "x4", "x8");
        for (name, strat) in [
            ("co", PulpConvStrategy::Co),
            ("ho", PulpConvStrategy::Ho),
            ("howo", PulpConvStrategy::HoWo),
        ] {
            print!("{name:>14}");
            let mut single = 0u64;
            for cores in [1usize, 2, 4, 8] {
                let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), cores);
                let mut out = vec![0i8; d.out_len()];
                pcap_q7_pulp(&input, &w, &bias, &d, shifts, strat, &mut out, &mut run);
                let cyc = run.cycles();
                if cores == 1 {
                    single = cyc;
                    print!(" {:>9.2}M", cyc as f64 / 1e6);
                } else {
                    print!(" {:>6.2}M/{:.1}x", cyc as f64 / 1e6, single as f64 / cyc as f64);
                }
            }
            println!();
        }
        println!(
            "  (ms at {} MHz: multiply cycles by {:.4})\n",
            board.clock_mhz,
            1.0 / (board.clock_mhz * 1e3)
        );
    }
    println!(
        "Takeaway (matches paper §5.2.2): no single strategy wins everywhere —\n\
         the best split follows the feature-map shape. `ho` degrades when\n\
         out_h < cores (load imbalance); `co` pays duplicated im2col gathers;\n\
         `howo` balances best for small grids."
    );
}
