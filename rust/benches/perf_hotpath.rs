//! L3 §Perf: host-side throughput of the serving hot path (EXPERIMENTS.md
//! §Perf targets: engine ≥ 10⁸ simulated MAC-events/s in release).
//!
//! Measures (a) the pre-arena allocating engine (the baseline the workspace
//! refactor is judged against), (b) the zero-alloc arena engine
//! (`forward_arm_into` — what serving runs), (c) the metered arena engine
//! (CycleCounter — what the latency simulator runs), (d) kernel-level
//! throughput of the capsule layer's dominant matmul, and (e) the traced
//! program path (span recording enabled) against the untraced one — the
//! `tracing_overhead` gate holds span recording to ≤2% RPS cost — and
//! (f) the approximate-routing program (division-free softmax/squash),
//! reporting its capsule-layer metered-cycle speedup and label agreement
//! vs the exact program. Results land in `BENCH_hotpath.json` so the
//! bench trajectory accumulates across PRs.

use capsnet_edge::bench_support::{bench_wall, write_bench_json};
use capsnet_edge::exec::{
    run_program, run_program_batched, run_program_traced, ArmBackend, Nonlinearity, Program,
    SimdBackend,
};
use capsnet_edge::formats::JsonValue;
use capsnet_edge::isa::{Board, CycleCounter, NullMeter};
use capsnet_edge::kernels::capsule::capsule_layer_q7_arm_nl_ws;
use capsnet_edge::kernels::legacy;
use capsnet_edge::kernels::matmul::{arm_mat_mult_q7_trb_scratch, MatPlacement};
use capsnet_edge::kernels::MatDims;
use capsnet_edge::model::{configs, ArmConv, QuantizedCapsNet};
use capsnet_edge::obs::TraceSink;
use capsnet_edge::testing::prop::XorShift;
use std::hint::black_box;

fn main() {
    let net = QuantizedCapsNet::random(configs::mnist(), 42);
    let mut rng = XorShift::new(7);
    let input = rng.i8_vec(net.config.input_len());
    let macs_per_fwd = {
        // conv + pcap + capsule MAC counts
        let c = net.config.conv_dims(0).macs();
        let p = net.config.pcap_dims().conv.macs();
        let d = net.config.caps_dims(0);
        let routing = 3 * (d.in_caps * d.out_dim + d.in_caps * d.out_dim) as u64;
        c + p + (d.weight_len() as u64) + routing
    };

    // (a) pre-arena baseline: allocating kernels, per-pair capsule matmuls.
    let us_legacy = bench_wall(3, 10, || {
        black_box(legacy::forward_arm_alloc(
            &net,
            black_box(&input),
            ArmConv::FastWithFallback,
            &mut NullMeter,
        ));
    });
    let macs_legacy = macs_per_fwd as f64 / (us_legacy / 1e6);
    println!(
        "pre-arena engine (alloc):   {us_legacy:.0} µs/inference  ->  {:.2}e6 MAC/s",
        macs_legacy / 1e6
    );

    // (b) serving engine: workspace arena + batched-GEMM capsule hot path.
    let mut ws = net.config.workspace();
    let mut out = vec![0i8; net.config.output_len()];
    let us = bench_wall(3, 10, || {
        net.forward_arm_into(
            black_box(&input),
            ArmConv::FastWithFallback,
            &mut ws,
            &mut out,
            &mut NullMeter,
        );
        black_box(&out);
    });
    let macs_per_s = macs_per_fwd as f64 / (us / 1e6);
    println!(
        "serving engine (arena):     {us:.0} µs/inference  ->  {:.2}e6 MAC/s ({:.1}M MACs/fwd, {:.2}x vs pre-arena)",
        macs_per_s / 1e6,
        macs_per_fwd as f64 / 1e6,
        us_legacy / us
    );

    // (b'') compile-once serving path: the program is lowered once
    // (Device/Fleet/Calibrator bind time) and only interpreted per
    // inference — no per-call lowering, no schedule dispatch. This is what
    // `Device::infer` actually runs; (b) above pays the wrapper's per-call
    // lowering on top.
    let prog = Program::lower_arm_uniform(&net, ArmConv::FastWithFallback, 1);
    let us_prog = bench_wall(3, 10, || {
        run_program(
            &net,
            &prog,
            black_box(&input),
            &mut ws,
            &mut out,
            &mut ArmBackend::new(&mut NullMeter),
        );
        black_box(&out);
    });
    let macs_prog = macs_per_fwd as f64 / (us_prog / 1e6);
    println!(
        "serving engine (program):   {us_prog:.0} µs/inference  ->  {:.2}e6 MAC/s ({:.2}x vs per-call lowering)",
        macs_prog / 1e6,
        us / us_prog
    );

    // (b''') traced serving path: the same compile-once program with the
    // observability ring recording one span per op. Both sides re-measure
    // back-to-back (rather than reusing us_prog) so the ratio compares runs
    // under the same machine state. The ≤2% gate is the tracing budget:
    // enabling spans on the worker loop must not cost measurable RPS.
    let mut sink = TraceSink::with_capacity(prog.ops().len() + 1);
    let us_traced = bench_wall(5, 40, || {
        run_program_traced(
            &net,
            &prog,
            black_box(&input),
            &mut ws,
            &mut out,
            &mut ArmBackend::new(&mut NullMeter),
            &mut sink,
        );
        black_box(&out);
    });
    let us_plain = bench_wall(5, 40, || {
        run_program(
            &net,
            &prog,
            black_box(&input),
            &mut ws,
            &mut out,
            &mut ArmBackend::new(&mut NullMeter),
        );
        black_box(&out);
    });
    let trace_ratio = us_plain / us_traced;
    println!(
        "traced engine (spans on):   {us_traced:.0} µs/inference  ->  {trace_ratio:.3}x RPS vs untraced"
    );

    // (b') batched serving engine: one forward_arm_batched_into over 8
    // images — each weight set streams once per batch instead of per image.
    let batch = 8usize;
    let inputs8 = rng.i8_vec(batch * net.config.input_len());
    let mut ws8 = net.config.workspace_batched(batch);
    let mut out8 = vec![0i8; batch * net.config.output_len()];
    let us_b8_total = bench_wall(3, 10, || {
        net.forward_arm_batched_into(
            black_box(&inputs8),
            batch,
            ArmConv::FastWithFallback,
            &mut ws8,
            &mut out8,
            &mut NullMeter,
        );
        black_box(&out8);
    });
    let us_b8 = us_b8_total / batch as f64;
    let macs_b8 = macs_per_fwd as f64 / (us_b8 / 1e6);
    println!(
        "serving engine (batch 8):   {us_b8:.0} µs/image      ->  {:.2}e6 MAC/s ({:.2}x vs batch 1)",
        macs_b8 / 1e6,
        us / us_b8
    );

    // (b'''') vectorized serving engine: the same 8-image batch through the
    // compile-once program, but dispatched to `SimdBackend` — the packed
    // i8→i32 GEMM (with the vector dot kernel when built with `--features
    // simd` on a host that detects one) instead of the instrumented scalar
    // kernels. This is what the Arm-pool serving workers and the calibrator
    // actually run; the floor in BENCH_hotpath.json holds it to ≥2× the
    // scalar compiled-program row above.
    let prog8 = Program::lower_arm_uniform(&net, ArmConv::FastWithFallback, batch);
    let mut simd = SimdBackend::for_config(&net.config, batch);
    let us_simd_total = bench_wall(3, 10, || {
        run_program_batched(
            &net,
            &prog8,
            black_box(&inputs8),
            batch,
            &mut ws8,
            &mut out8,
            &mut simd,
        );
        black_box(&out8);
    });
    let us_simd = us_simd_total / batch as f64;
    let macs_simd = macs_per_fwd as f64 / (us_simd / 1e6);
    println!(
        "serving engine (simd b8):   {us_simd:.0} µs/image      ->  {:.2}e6 MAC/s ({:.2}x vs scalar program, simd feature {})",
        macs_simd / 1e6,
        us_prog / us_simd,
        if SimdBackend::supported() { "vectorized" } else { "scalar-dot" }
    );

    // (c) metered engine: CycleCounter (the fleet simulator path).
    let board = Board::stm32h755();
    let us_m = bench_wall(3, 10, || {
        let mut cc = CycleCounter::new(board.cost_model());
        net.forward_arm_into(
            black_box(&input),
            ArmConv::FastWithFallback,
            &mut ws,
            &mut out,
            &mut cc,
        );
        black_box(cc.cycles());
    });
    println!(
        "metered engine (CycleCounter): {us_m:.0} µs/inference (metering overhead {:.0}%)",
        100.0 * (us_m - us) / us
    );

    // (e) approximate routing: the compile-once program with every capsule
    // layer lowered onto the division-free approx softmax/squash kernels —
    // what the planner selects under a nonzero accuracy budget. Three
    // numbers: host wall throughput, the deterministic metered-cycle
    // speedup of the capsule layer alone (CycleCounter, M4 cost model —
    // the quantity the planner's argmin actually prices), and label
    // agreement vs the exact program over random inputs (the quantity the
    // accuracy budget bounds).
    let nl_approx = vec![Nonlinearity::Approx; net.caps.len()];
    let sched_fast = vec![ArmConv::FastWithFallback; net.convs.len() + 1];
    let prog_approx = Program::lower_arm_nl(&net, &sched_fast, &nl_approx, 1);
    let us_approx = bench_wall(3, 10, || {
        run_program(
            &net,
            &prog_approx,
            black_box(&input),
            &mut ws,
            &mut out,
            &mut ArmBackend::new(&mut NullMeter),
        );
        black_box(&out);
    });
    let macs_approx = macs_per_fwd as f64 / (us_approx / 1e6);

    let d0 = net.config.caps_dims(0);
    let r0 = net.config.caps_layers[0].routings;
    let caps_in = rng.i8_vec(d0.input_len());
    let mut caps_scratch = vec![0i8; d0.scratch_len()];
    let mut caps_out = vec![0i8; d0.output_len()];
    let mut caps_cycles = |nonlin: Nonlinearity| {
        let mut cc = CycleCounter::new(board.cost_model());
        capsule_layer_q7_arm_nl_ws(
            &caps_in,
            &net.caps[0].w,
            &d0,
            r0,
            &net.caps[0].shifts,
            nonlin,
            &mut caps_scratch,
            &mut caps_out,
            &mut cc,
        );
        cc.cycles()
    };
    let cyc_caps_exact = caps_cycles(Nonlinearity::Exact);
    let cyc_caps_approx = caps_cycles(Nonlinearity::Approx);
    let caps_speedup = cyc_caps_exact as f64 / cyc_caps_approx as f64;

    let agree_imgs = 32usize;
    let mut out_exact = vec![0i8; net.config.output_len()];
    let mut agree = 0usize;
    for _ in 0..agree_imgs {
        let img = rng.i8_vec(net.config.input_len());
        let mut nm = NullMeter;
        let mut be = ArmBackend::new(&mut nm);
        run_program(&net, &prog, &img, &mut ws, &mut out_exact, &mut be);
        run_program(&net, &prog_approx, &img, &mut ws, &mut out, &mut be);
        if net.classify(&out_exact) == net.classify(&out) {
            agree += 1;
        }
    }
    let agreement = agree as f64 / agree_imgs as f64;
    println!(
        "approx routing (program):   {us_approx:.0} µs/inference  ->  {:.2}e6 MAC/s | caps layer {caps_speedup:.2}x metered cycles vs exact, {:.0}% label agreement",
        macs_approx / 1e6,
        100.0 * agreement
    );

    // (d) capsule-layer matmul kernel throughput (scratch variant).
    let dims = MatDims::new(64, 256, 64);
    let a = rng.i8_vec(dims.a_len());
    let b = rng.i8_vec(dims.b_len());
    let mut mm_out = vec![0i8; dims.out_len()];
    let mut mm_scratch = vec![0i8; dims.scratch_len()];
    let us_k = bench_wall(5, 20, || {
        arm_mat_mult_q7_trb_scratch(
            black_box(&a), black_box(&b), dims, 5, &mut mm_out,
            MatPlacement::weights_a(), &mut mm_scratch, &mut NullMeter,
        );
        black_box(&mm_out);
    });
    let kmacs = (dims.rows_a * dims.cols_a * dims.cols_b) as f64;
    let kernel_macs_per_s = kmacs / (us_k / 1e6);
    println!(
        "q7 matmul kernel 64x256x64: {us_k:.0} µs  ->  {:.2}e6 MAC/s",
        kernel_macs_per_s / 1e6
    );

    // target checks: L3 absolute target + the arena-refactor speedup floor.
    let l3_ok = macs_per_s >= 1e8;
    let speedup = us_legacy / us;
    let speedup_ok = speedup >= 2.0;
    println!("\nL3 target (>= 1e8 MAC/s serving engine): {}", if l3_ok { "PASS" } else { "MISS" });
    println!(
        "arena speedup target (>= 2x vs pre-arena): {:.2}x {}",
        speedup,
        if speedup_ok { "PASS" } else { "MISS" }
    );
    let trace_ok = trace_ratio >= 0.98;
    println!(
        "tracing overhead target (<= 2% RPS cost): {:.3}x {}",
        trace_ratio,
        if trace_ok { "PASS" } else { "MISS" }
    );

    write_bench_json(
        "BENCH_hotpath.json",
        &JsonValue::obj(vec![
            ("bench", JsonValue::str("hotpath")),
            ("model", JsonValue::str("mnist")),
            ("macs_per_forward", JsonValue::int(macs_per_fwd as i64)),
            (
                "baseline_pre_arena",
                JsonValue::obj(vec![
                    ("us_per_inference", JsonValue::num(us_legacy)),
                    ("mac_per_s", JsonValue::num(macs_legacy)),
                ]),
            ),
            (
                "serving_arena",
                JsonValue::obj(vec![
                    ("us_per_inference", JsonValue::num(us)),
                    ("mac_per_s", JsonValue::num(macs_per_s)),
                ]),
            ),
            (
                "serving_program",
                JsonValue::obj(vec![
                    ("us_per_inference", JsonValue::num(us_prog)),
                    ("mac_per_s", JsonValue::num(macs_prog)),
                ]),
            ),
            (
                "serving_arena_batch8",
                JsonValue::obj(vec![
                    ("us_per_image", JsonValue::num(us_b8)),
                    ("mac_per_s", JsonValue::num(macs_b8)),
                    ("speedup_vs_batch1", JsonValue::num(us / us_b8)),
                ]),
            ),
            (
                "serving_simd",
                JsonValue::obj(vec![
                    ("us_per_image", JsonValue::num(us_simd)),
                    ("mac_per_s", JsonValue::num(macs_simd)),
                    ("speedup_vs_program", JsonValue::num(us_prog / us_simd)),
                    ("vector_isa_detected", JsonValue::Bool(SimdBackend::supported())),
                ]),
            ),
            (
                "serving_approx",
                JsonValue::obj(vec![
                    ("us_per_inference", JsonValue::num(us_approx)),
                    ("mac_per_s", JsonValue::num(macs_approx)),
                    ("caps_cycle_speedup_vs_exact", JsonValue::num(caps_speedup)),
                    ("agreement_ratio_vs_exact", JsonValue::num(agreement)),
                ]),
            ),
            (
                "metered",
                JsonValue::obj(vec![("us_per_inference", JsonValue::num(us_m))]),
            ),
            (
                "tracing_overhead",
                JsonValue::obj(vec![
                    ("us_per_inference_enabled", JsonValue::num(us_traced)),
                    ("rps_ratio_vs_disabled", JsonValue::num(trace_ratio)),
                ]),
            ),
            (
                "matmul_kernel_64x256x64",
                JsonValue::obj(vec![
                    ("us", JsonValue::num(us_k)),
                    ("mac_per_s", JsonValue::num(kernel_macs_per_s)),
                ]),
            ),
            ("speedup_vs_pre_arena", JsonValue::num(speedup)),
            ("pass_l3_1e8_mac_per_s", JsonValue::Bool(l3_ok)),
            ("pass_speedup_2x", JsonValue::Bool(speedup_ok)),
            ("pass_tracing_overhead_2pct", JsonValue::Bool(trace_ok)),
        ]),
    );
}
