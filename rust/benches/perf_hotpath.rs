//! L3 §Perf: host-side throughput of the serving hot path (EXPERIMENTS.md
//! §Perf targets: engine ≥ 10⁸ simulated MAC-events/s in release).
//!
//! Measures (a) the raw q7 engine (NullMeter — what serving runs), (b) the
//! metered engine (CycleCounter — what the latency simulator runs), and
//! (c) kernel-level throughput of the capsule layer's dominant matmul.

use capsnet_edge::bench_support::bench_wall;
use capsnet_edge::isa::{Board, CycleCounter, NullMeter};
use capsnet_edge::kernels::matmul::{arm_mat_mult_q7_trb, MatPlacement};
use capsnet_edge::kernels::MatDims;
use capsnet_edge::model::{configs, ArmConv, QuantizedCapsNet};
use capsnet_edge::testing::prop::XorShift;
use std::hint::black_box;

fn main() {
    let net = QuantizedCapsNet::random(configs::mnist(), 42);
    let mut rng = XorShift::new(7);
    let input = rng.i8_vec(net.config.input_len());
    let macs_per_fwd = {
        // conv + pcap + capsule MAC counts
        let c = net.config.conv_dims(0).macs();
        let p = net.config.pcap_dims().conv.macs();
        let d = net.config.caps_dims(0);
        let routing = 3 * (d.in_caps * d.out_dim + d.in_caps * d.out_dim) as u64;
        c + p + (d.weight_len() as u64) + routing
    };

    // (a) serving engine: NullMeter
    let us = bench_wall(3, 10, || {
        black_box(net.forward_arm(black_box(&input), ArmConv::FastWithFallback, &mut NullMeter));
    });
    let macs_per_s = macs_per_fwd as f64 / (us / 1e6);
    println!(
        "serving engine (NullMeter): {us:.0} µs/inference  ->  {:.2}e6 MAC/s ({:.1}M MACs/fwd)",
        macs_per_s / 1e6,
        macs_per_fwd as f64 / 1e6
    );

    // (b) metered engine: CycleCounter (the fleet simulator path)
    let board = Board::stm32h755();
    let us_m = bench_wall(3, 10, || {
        let mut cc = CycleCounter::new(board.cost_model());
        black_box(net.forward_arm(black_box(&input), ArmConv::FastWithFallback, &mut cc));
        black_box(cc.cycles());
    });
    println!(
        "metered engine (CycleCounter): {us_m:.0} µs/inference (metering overhead {:.0}%)",
        100.0 * (us_m - us) / us
    );

    // (c) capsule-layer matmul kernel throughput
    let dims = MatDims::new(64, 256, 64);
    let a = rng.i8_vec(dims.a_len());
    let b = rng.i8_vec(dims.b_len());
    let mut out = vec![0i8; dims.out_len()];
    let us_k = bench_wall(5, 20, || {
        arm_mat_mult_q7_trb(
            black_box(&a), black_box(&b), dims, 5, &mut out,
            MatPlacement::weights_a(), &mut NullMeter,
        );
        black_box(&out);
    });
    let kmacs = (dims.rows_a * dims.cols_a * dims.cols_b) as f64;
    println!(
        "q7 matmul kernel 64x256x64: {us_k:.0} µs  ->  {:.2}e6 MAC/s",
        kmacs / (us_k / 1e6) / 1e6
    );

    // target check (EXPERIMENTS.md §Perf): >= 1e8 MAC-events/s simulated
    let ok = macs_per_s >= 1e8;
    println!("\nL3 target (>= 1e8 MAC/s serving engine): {}", if ok { "PASS" } else { "MISS" });
}
