//! Golden event-stream regression: proves the workspace-arena/batched-GEMM
//! refactor left the simulated cycle counts untouched.
//!
//! The pre-refactor engine is preserved verbatim in `kernels::legacy`; for
//! fixed seeds and dims (including the paper's Table 7/8 capsule workloads
//! and whole-network forwards) the refactored hot path must emit exactly the
//! same per-event counts on every simulated core. Counts determine cycles,
//! so count equality ⇒ Tables 3–8 equality — "cycles unchanged" is proved,
//! not asserted.

use capsnet_edge::isa::{ClusterRun, CostModel, CycleCounter};
use capsnet_edge::kernels::capsule::{
    capsule_layer_q7_arm, capsule_layer_q7_riscv, CapsuleDims, CapsuleShifts,
};
use capsnet_edge::kernels::conv::PulpConvStrategy;
use capsnet_edge::kernels::legacy;
use capsnet_edge::model::{configs, ArmConv, QuantizedCapsNet};
use capsnet_edge::testing::prop::XorShift;

/// Capsule workloads under regression: paper Table 7/8 dims plus edge cases
/// (fewer input capsules than cluster cores, single routing iteration).
fn capsule_cases() -> Vec<(CapsuleDims, usize)> {
    vec![
        (configs::mnist().caps_dims(0), 3),     // 10×1024×6×4 (L)
        (configs::cifar10().caps_dims(0), 3),   // 10×64×5×4 (S)
        (CapsuleDims::new(5, 40, 6, 4), 2),
        (CapsuleDims::new(3, 5, 4, 3), 3),      // in_caps < 8 cores
        (CapsuleDims::new(4, 16, 2, 2), 1),     // no agreement phase
    ]
}

#[test]
fn capsule_arm_event_counts_match_legacy() {
    for (d, routings) in capsule_cases() {
        let mut rng = XorShift::new(0xBEEF);
        let u = rng.i8_vec(d.input_len());
        let w = rng.i8_vec(d.weight_len());
        let shifts = CapsuleShifts::uniform(routings, 4, 5);

        let mut out_new = vec![0i8; d.output_len()];
        let mut cc_new = CycleCounter::new(CostModel::cortex_m4());
        capsule_layer_q7_arm(&u, &w, &d, routings, &shifts, &mut out_new, &mut cc_new);

        let mut out_old = vec![0i8; d.output_len()];
        let mut cc_old = CycleCounter::new(CostModel::cortex_m4());
        legacy::capsule_layer_q7_arm_alloc(&u, &w, &d, routings, &shifts, &mut out_old, &mut cc_old);

        assert_eq!(out_new, out_old, "outputs diverged for {d:?} r={routings}");
        assert_eq!(
            cc_new.counts(),
            cc_old.counts(),
            "event counts diverged for {d:?} r={routings}"
        );
        assert_eq!(cc_new.cycles(), cc_old.cycles());
    }
}

#[test]
fn capsule_riscv_event_counts_match_legacy_per_core() {
    let model = CostModel::gap8_cluster_core();
    for (d, routings) in capsule_cases() {
        for cores in [1usize, 2, 8] {
            let mut rng = XorShift::new(0xBEEF);
            let u = rng.i8_vec(d.input_len());
            let w = rng.i8_vec(d.weight_len());
            let shifts = CapsuleShifts::uniform(routings, 4, 5);

            let mut out_new = vec![0i8; d.output_len()];
            let mut run_new = ClusterRun::new(&model, cores);
            capsule_layer_q7_riscv(&u, &w, &d, routings, &shifts, &mut out_new, &mut run_new);

            let mut out_old = vec![0i8; d.output_len()];
            let mut run_old = ClusterRun::new(&model, cores);
            legacy::capsule_layer_q7_riscv_alloc(
                &u, &w, &d, routings, &shifts, &mut out_old, &mut run_old,
            );

            assert_eq!(out_new, out_old, "{d:?} r={routings} x{cores}");
            for (c, (new_core, old_core)) in
                run_new.cores.iter().zip(run_old.cores.iter()).enumerate()
            {
                assert_eq!(
                    new_core.counts(),
                    old_core.counts(),
                    "core {c} counts diverged for {d:?} r={routings} x{cores}"
                );
            }
            assert_eq!(run_new.cycles(), run_old.cycles());
        }
    }
}

#[test]
fn forward_arm_event_counts_match_legacy() {
    for (cfg, conv) in [
        (configs::mnist(), ArmConv::Basic),
        (configs::mnist(), ArmConv::FastWithFallback),
        (configs::cifar10(), ArmConv::FastWithFallback),
    ] {
        let name = cfg.name.clone();
        let net = QuantizedCapsNet::random(cfg, 99);
        let mut rng = XorShift::new(0xF00D);
        let input = rng.i8_vec(net.config.input_len());

        let mut cc_new = CycleCounter::new(CostModel::cortex_m7());
        let out_new = net.forward_arm(&input, conv, &mut cc_new);

        let mut cc_old = CycleCounter::new(CostModel::cortex_m7());
        let out_old = legacy::forward_arm_alloc(&net, &input, conv, &mut cc_old);

        assert_eq!(out_new, out_old, "{name} {conv:?}");
        assert_eq!(cc_new.counts(), cc_old.counts(), "{name} {conv:?}");
    }
}

#[test]
fn forward_riscv_event_counts_match_legacy() {
    let model = CostModel::gap8_cluster_core();
    let net = QuantizedCapsNet::random(configs::cifar10(), 99);
    let mut rng = XorShift::new(0xF00D);
    let input = rng.i8_vec(net.config.input_len());
    for strategy in [PulpConvStrategy::Co, PulpConvStrategy::Ho, PulpConvStrategy::HoWo] {
        for cores in [1usize, 8] {
            let mut run_new = ClusterRun::new(&model, cores);
            let out_new = net.forward_riscv(&input, strategy, &mut run_new);

            let mut run_old = ClusterRun::new(&model, cores);
            let out_old = legacy::forward_riscv_alloc(&net, &input, strategy, &mut run_old);

            assert_eq!(out_new, out_old, "{strategy:?} x{cores}");
            for (c, (new_core, old_core)) in
                run_new.cores.iter().zip(run_old.cores.iter()).enumerate()
            {
                assert_eq!(
                    new_core.counts(),
                    old_core.counts(),
                    "core {c} diverged, {strategy:?} x{cores}"
                );
            }
        }
    }
}
