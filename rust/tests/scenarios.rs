//! Tier-1 scenario suite: deterministic traffic traces × fault plans.
//!
//! Each scenario drives the SLO-aware pooled serving path
//! (`Fleet::serve_pooled_with` + `ServeConfig::slo_ms`) with a seeded
//! [`TraceSpec`] arrival stream and a [`FaultPlan`], then pins the
//! robustness contract:
//!
//! * **Totality** — every request id appears exactly once, as a served
//!   output or a typed rejection; nothing is dropped or duplicated.
//! * **Bit-identity** — every served output equals the reference int-8
//!   computation (and survives fault-induced re-dispatch unchanged).
//! * **Deadline soundness** — with an SLO set, p99 virtual latency ≤ SLO
//!   and `deadline_misses() == 0`: the control plane sheds instead of
//!   serving late.
//! * **Zero panics** — overload, death, flakiness, and heavy-tail arrivals
//!   all resolve to values, never unwinds.
//! * **Span totality** — with tracing on, the merged span log accounts for
//!   every request's lifecycle and every execute window is well-formed.
//!
//! Everything here is deterministic: seeded traces, the virtual device
//! clock, and seeded random models (no artifacts required).

use capsnet_edge::coordinator::{
    BatchPolicy, Fault, FaultPlan, Fleet, RejectReason, Request, RouterPolicy, ServeConfig,
    ServeReport, TraceKind, TraceSpec,
};
use capsnet_edge::isa::Board;
use capsnet_edge::model::{configs, QuantizedCapsNet};
use capsnet_edge::testing::prop::XorShift;
use std::collections::BTreeSet;
use std::sync::Arc;

fn fleet(boards: &[Board], seed: u64) -> (Fleet, Arc<QuantizedCapsNet>) {
    let model = Arc::new(QuantizedCapsNet::random(configs::cifar10(), seed));
    let mut f = Fleet::new(RouterPolicy::RoundRobin);
    for b in boards {
        f.add_device(b.clone(), model.clone()).unwrap();
    }
    (f, model)
}

fn traced_requests(
    model: &QuantizedCapsNet,
    trace: &TraceSpec,
    n: usize,
    seed: u64,
) -> Vec<Request> {
    let mut rng = XorShift::new(seed);
    trace.requests(n, |_| (rng.i8_vec(model.config.input_len()), None))
}

/// Aggregate fleet service rate: requests per virtual second if every
/// device ran back-to-back batches of one.
fn capacity_rps(f: &Fleet) -> f64 {
    f.devices.iter().map(|d| 1e3 / d.inference_ms).sum()
}

fn min_inference_ms(f: &Fleet) -> f64 {
    f.devices.iter().map(|d| d.inference_ms).fold(f64::INFINITY, f64::min)
}

/// Every id in `0..n` is accounted for exactly once (served XOR rejected).
fn assert_total(n: usize, report: &ServeReport, ctx: &str) {
    let served: BTreeSet<u64> = report.outputs.iter().map(|&(id, _)| id).collect();
    let shed: BTreeSet<u64> = report.rejections.iter().map(|r| r.id).collect();
    assert_eq!(served.len(), report.outputs.len(), "{ctx}: duplicate served ids");
    assert_eq!(shed.len(), report.rejections.len(), "{ctx}: duplicate rejected ids");
    assert!(served.is_disjoint(&shed), "{ctx}: an id was both served and rejected");
    assert_eq!(served.len() + shed.len(), n, "{ctx}: accounting is not total");
}

/// With deadline shedding on, completions are in-SLO *by construction* —
/// the virtual clock that projects a batch's completion is the same clock
/// the pre-dispatch shed gate consulted.
fn assert_in_slo(report: &ServeReport, ctx: &str) {
    let slo = report.slo_ms.expect("scenario runs set an SLO");
    let p99 = report.virt_latency_stats().p99;
    assert!(p99 <= slo + 1e-6, "{ctx}: p99 {p99:.3} ms exceeds slo {slo:.3} ms");
    assert_eq!(report.deadline_misses(), 0, "{ctx}: a completion landed past its deadline");
}

#[test]
fn every_trace_crossed_with_every_fault_plan_keeps_the_contract() {
    let (mut f, model) = fleet(&[Board::stm32h755(), Board::stm32h755()], 71);
    let n = 24usize;
    let slo_ms = 8.0 * min_inference_ms(&f);
    let rps = capacity_rps(&f);
    let plans: [(&str, FaultPlan); 4] = [
        ("fault-free", FaultPlan::none()),
        ("die", FaultPlan { faults: vec![Fault::Die { device: 0, after_requests: 4 }] }),
        ("flaky", FaultPlan { faults: vec![Fault::Flaky { device: 1, every: 3 }] }),
        (
            "spike",
            FaultPlan {
                faults: vec![Fault::LatencySpike { device: 0, factor: 6.0, from: 2, count: 4 }],
            },
        ),
    ];
    for kind in TraceKind::all() {
        let trace = TraceSpec { kind, rps, seed: 5 };
        let reqs = traced_requests(&model, &trace, n, 72);
        // Reference outputs: one sequential batch on a single device.
        // Batch composition never changes a member's int-8 output, so this
        // is the bit-identity oracle for every scenario run.
        let inputs: Vec<&[i8]> = reqs.iter().map(|r| r.input_q.as_slice()).collect();
        let expected = f.devices[0].infer_batch(&inputs);
        for (plan_name, plan) in &plans {
            let ctx = format!("{}/{}", kind.name(), plan_name);
            let cfg = ServeConfig {
                retry_budget: 4,
                slo_ms: Some(slo_ms),
                faults: plan.clone(),
                ..ServeConfig::default()
            };
            let report =
                f.serve_pooled_with(&reqs, BatchPolicy::new(slo_ms / 4.0, 4), 2, &cfg).unwrap();
            assert_total(n, &report, &ctx);
            assert_in_slo(&report, &ctx);
            for (id, out) in report.outputs_by_id() {
                assert_eq!(out, expected[id as usize], "{ctx}: request {id} not bit-identical");
            }
        }
    }
}

#[test]
fn bursty_overload_sheds_typed_and_all_completions_meet_deadlines() {
    let (f, model) = fleet(&[Board::stm32h755(), Board::stm32h755()], 73);
    let n = 32usize;
    let slo_ms = 6.0 * min_inference_ms(&f);
    let trace = TraceSpec { kind: TraceKind::Bursty, rps: 2.5 * capacity_rps(&f), seed: 9 };
    let reqs = traced_requests(&model, &trace, n, 74);
    let cfg = ServeConfig { slo_ms: Some(slo_ms), ..ServeConfig::default() };
    let report = f.serve_pooled_with(&reqs, BatchPolicy::new(slo_ms / 4.0, 4), 2, &cfg).unwrap();

    assert_total(n, &report, "bursty-overload");
    assert_in_slo(&report, "bursty-overload");
    let deadline_shed =
        report.rejections.iter().filter(|r| r.reason == RejectReason::DeadlineExceeded).count();
    assert!(
        deadline_shed > 0,
        "2.5x-capacity bursts must shed something: {:?}",
        report.rejections
    );
    assert_eq!(
        report.faults.deadline_sheds as usize, deadline_shed,
        "counter must agree with the typed rejections"
    );
    assert!(!report.outputs.is_empty(), "overload must degrade, not starve");
    assert!(report.goodput_rps() > 0.0);
}

#[test]
fn degraded_mixed_isa_pool_under_sustained_overload_keeps_the_contract() {
    // A GAP-8 + Cortex-M pool loses its fast board at request zero while a
    // constant trace arrives at 2x the *healthy* capacity: the survivor
    // serves what fits in budget, sheds the rest typed, and every served
    // output is bit-identical to the fault-free run of the same trace.
    let (f, model) = fleet(&[Board::gapuino(), Board::stm32h755()], 75);
    let n = 24usize;
    let slo_ms = 8.0 * f.devices[1].inference_ms; // budget on the survivor's clock
    let trace = TraceSpec { kind: TraceKind::Constant, rps: 2.0 * capacity_rps(&f), seed: 3 };
    let reqs = traced_requests(&model, &trace, n, 76);
    let policy = BatchPolicy::new(slo_ms / 4.0, 4);

    let clean = f.serve_pooled(&reqs, policy, 2).unwrap();
    assert_eq!(clean.outputs.len(), n, "deadline-blind fault-free run serves everything");

    let cfg = ServeConfig {
        slo_ms: Some(slo_ms),
        faults: FaultPlan { faults: vec![Fault::Die { device: 0, after_requests: 0 }] },
        ..ServeConfig::default()
    };
    let report = f.serve_pooled_with(&reqs, policy, 2, &cfg).unwrap();
    assert_total(n, &report, "degraded-overload");
    assert_in_slo(&report, "degraded-overload");
    assert!(
        report.rejections.iter().any(|r| r.reason == RejectReason::DeadlineExceeded),
        "a dead board under 2x load must force deadline sheds: {:?}",
        report.rejections
    );
    assert!(!report.outputs.is_empty(), "the surviving board must still serve");
    let expected = clean.outputs_by_id();
    for (id, out) in report.outputs_by_id() {
        let reference = &expected.iter().find(|(eid, _)| *eid == id).unwrap().1;
        assert_eq!(&out, reference, "survivor request {id} not bit-identical");
    }
}

#[test]
fn traced_scenario_produces_a_total_well_scoped_span_log() {
    // Span totality under overload + faults: the trace must account for
    // every request (one arrival each; served ⇒ admitted and never shed;
    // rejected ⇒ shed exactly once with the rejection's own typed reason),
    // execute windows must not overlap per device, and per-layer op spans
    // must nest inside an execute window on their device.
    use capsnet_edge::obs::{SpanKind, TraceConfig, DEV_NONE};
    use std::collections::BTreeMap;
    let (f, model) = fleet(&[Board::stm32h755(), Board::stm32h755()], 79);
    let n = 32usize;
    let slo_ms = 6.0 * min_inference_ms(&f);
    let trace = TraceSpec { kind: TraceKind::Bursty, rps: 2.5 * capacity_rps(&f), seed: 9 };
    let reqs = traced_requests(&model, &trace, n, 80);
    let policy = BatchPolicy::new(slo_ms / 4.0, 4);

    let untraced = f.serve_pooled(&reqs, policy, 2).unwrap();
    assert!(untraced.trace.is_none(), "tracing is strictly opt-in");

    let cfg = ServeConfig {
        retry_budget: 2,
        slo_ms: Some(slo_ms),
        faults: FaultPlan { faults: vec![Fault::Flaky { device: 1, every: 3 }] },
        trace: Some(TraceConfig::default()),
        ..ServeConfig::default()
    };
    let report = f.serve_pooled_with(&reqs, policy, 2, &cfg).unwrap();
    assert_total(n, &report, "traced-scenario");
    assert!(!report.rejections.is_empty(), "2.5x-capacity bursts must shed something");
    let log = report.trace.as_ref().expect("tracing was configured");
    assert_eq!(log.dropped, 0, "the default ring must hold a 32-request scenario");
    assert_eq!(log.devices.len(), 2);

    let mut arrivals: BTreeMap<u64, usize> = BTreeMap::new();
    let mut admits: BTreeMap<u64, usize> = BTreeMap::new();
    let mut sheds: BTreeMap<u64, Vec<RejectReason>> = BTreeMap::new();
    for r in &log.records {
        match r.kind {
            SpanKind::Arrival => *arrivals.entry(r.req).or_default() += 1,
            SpanKind::Admit { .. } => *admits.entry(r.req).or_default() += 1,
            SpanKind::Shed { reason, .. } => sheds.entry(r.req).or_default().push(reason),
            _ => {}
        }
    }
    for id in 0..n as u64 {
        assert_eq!(arrivals.get(&id), Some(&1), "request {id}: exactly one arrival span");
    }
    for (id, _) in &report.outputs {
        assert!(admits.get(id).copied().unwrap_or(0) >= 1, "served {id} has no admit span");
        assert!(!sheds.contains_key(id), "served {id} must not carry a terminal shed span");
    }
    for r in &report.rejections {
        assert_eq!(
            sheds.get(&r.id).map(Vec::as_slice),
            Some(&[r.reason][..]),
            "rejected {} needs exactly one shed span with its typed reason",
            r.id
        );
    }

    let mut exec_by_dev: BTreeMap<u16, Vec<(u64, u64)>> = BTreeMap::new();
    for r in &log.records {
        if matches!(r.kind, SpanKind::Execute { .. }) {
            assert!(r.t1_us >= r.t0_us, "execute span runs backwards");
            assert_ne!(r.device, DEV_NONE, "execute spans are device-scoped");
            exec_by_dev.entry(r.device).or_default().push((r.t0_us, r.t1_us));
        }
    }
    assert!(!exec_by_dev.is_empty(), "a serving run must record execute spans");
    for (dev, mut spans) in exec_by_dev {
        spans.sort_unstable();
        for w in spans.windows(2) {
            assert!(w[1].0 >= w[0].1, "device {dev}: execute spans overlap: {w:?}");
        }
    }

    let mut saw_layer_op = false;
    for r in &log.records {
        if matches!(r.kind, SpanKind::LayerOp { .. }) {
            saw_layer_op = true;
            let enclosed = log.records.iter().any(|e| {
                matches!(e.kind, SpanKind::Execute { .. })
                    && e.device == r.device
                    && e.t0_us <= r.t0_us
                    && r.t1_us <= e.t1_us
            });
            assert!(enclosed, "layer op is not nested in any execute window: {r:?}");
        }
    }
    assert!(saw_layer_op, "per-layer attribution must reach the merged log");
    assert!(log.records.iter().any(|r| matches!(r.kind, SpanKind::BatchClose { .. })));
    if report.faults.retries > 0 {
        assert!(
            log.records.iter().any(|r| matches!(r.kind, SpanKind::Retry { .. })),
            "observed retries must appear as retry spans"
        );
    }
}

#[test]
fn heavy_tail_trace_with_zero_retry_budget_exhausts_typed_not_panicking() {
    let (f, model) = fleet(&[Board::stm32h755(), Board::stm32h755()], 77);
    let n = 24usize;
    let slo_ms = 10.0 * min_inference_ms(&f);
    let trace = TraceSpec { kind: TraceKind::Pareto, rps: capacity_rps(&f), seed: 13 };
    let reqs = traced_requests(&model, &trace, n, 78);
    let cfg = ServeConfig {
        retry_budget: 0,
        slo_ms: Some(slo_ms),
        faults: FaultPlan { faults: vec![Fault::Flaky { device: 0, every: 2 }] },
        ..ServeConfig::default()
    };
    let report = f.serve_pooled_with(&reqs, BatchPolicy::new(slo_ms / 4.0, 4), 2, &cfg).unwrap();
    assert_total(n, &report, "pareto-flaky");
    assert_in_slo(&report, "pareto-flaky");
    let exhausted =
        report.rejections.iter().any(|r| matches!(r.reason, RejectReason::RetriesExhausted { .. }));
    assert!(exhausted, "budget 0 under a flaky board must exhaust typed: {:?}", report.rejections);
}
