//! CLI smoke tests: drive the binary end-to-end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_capsnet-edge"))
}

#[test]
fn help_lists_subcommands() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["configs", "tables", "plan", "infer", "serve-sim", "serve", "profile", "runtime-check"]
    {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
    // `serve` must advertise the fault-injection grammar ("serve" alone
    // would match the serve-sim line above) and the SLO/traffic flags.
    assert!(text.contains("--inject-faults"), "help missing fault injection:\n{text}");
    assert!(text.contains("--slo-ms"), "help missing SLO flag:\n{text}");
    assert!(text.contains("--trace"), "help missing trace flag:\n{text}");
    assert!(text.contains("--trace-out"), "help missing trace export flag:\n{text}");
    assert!(
        text.contains("constant|bursty|diurnal|pareto"),
        "help missing the trace grammar:\n{text}"
    );
}

#[test]
fn plan_prints_strategy_table_and_memory_map() {
    let out = bin().args(["plan", "--config", "cifar10", "--board", "gap8"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("deployment plan v2"), "{text}");
    assert!(text.contains("pulp-"), "no PULP strategy printed:\n{text}");
    assert!(text.contains("arena"), "no memory map printed:\n{text}");
    assert!(text.contains("pcap"), "pcap layer missing:\n{text}");
}

#[test]
fn plan_uniform_splits_pins_the_full_cluster() {
    let out = bin()
        .args(["plan", "--config", "cifar10", "--board", "gap8", "--uniform-splits"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    // Every chosen layer row shows the full 8-core cluster under
    // --uniform-splits (the candidate list still shows sub-splits).
    let layer_rows: Vec<&str> = text
        .lines()
        .filter(|l| l.contains(" | ") && (l.contains(" pulp-") || l.contains(" routing ")))
        .collect();
    assert!(!layer_rows.is_empty(), "no layer rows found:\n{text}");
    for line in layer_rows {
        let cores = line.split_whitespace().nth(3).unwrap_or("");
        assert_eq!(cores, "8", "non-uniform split in: {line}");
    }
}

#[test]
fn plan_saves_a_versioned_artifact() {
    let path = std::env::temp_dir().join("capsnet_cli_smoke_plan.json");
    let _ = std::fs::remove_file(&path);
    let out = bin()
        .args(["plan", "--config", "mnist", "--board", "m7", "--batch", "4", "--save"])
        .arg(&path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&path).expect("plan artifact written");
    assert!(text.contains("\"plan_version\": 2"), "{text}");
    assert!(text.contains("\"arm-"), "{text}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn plan_rejects_unknown_config() {
    let out = bin().args(["plan", "--config", "imagenet"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown config"));
}

#[test]
fn configs_prints_table1() {
    let out = bin().arg("configs").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("mnist") && text.contains("smallnorb") && text.contains("cifar10"));
    assert!(text.contains("10x1024x6x4"), "capsule workload missing:\n{text}");
    assert!(text.contains("74.99%"), "saving missing");
}

#[test]
fn tables_3_and_4_run() {
    for t in ["3", "4"] {
        let out = bin().args(["tables", t]).output().unwrap();
        assert!(out.status.success(), "tables {t} failed");
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("mean |rel err| vs paper"));
    }
}

#[test]
fn unknown_command_errors() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn infer_requires_model_flag() {
    let out = bin().arg("infer").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--model"));
}

#[test]
fn serve_requires_model_flag() {
    let out = bin().arg("serve").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--model"));
}

#[test]
fn profile_requires_model_flag() {
    let out = bin().arg("profile").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--model"));
}

#[test]
fn serve_rejects_unwritable_trace_out_path() {
    // The trace sink file is created (truncated) before the run starts, so
    // an unwritable path must fail fast with the flag named on stderr.
    let out = bin()
        .args([
            "serve", "--model", "/nonexistent.cnq", "--eval", "/nonexistent.npt",
            "--trace-out", "/nonexistent-dir/trace.json",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--trace-out"));
}

#[test]
fn serve_rejects_malformed_fault_spec() {
    // The fault plan parses before any artifact loads, so dummy paths are
    // fine — the grammar error must surface, typed, on stderr.
    let out = bin()
        .args([
            "serve", "--model", "/nonexistent.cnq", "--eval", "/nonexistent.npt",
            "--inject-faults", "explode:4",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--inject-faults"), "{err}");
    assert!(err.contains("unknown fault kind"), "{err}");
}

#[test]
fn serve_rejects_malformed_trace_specs() {
    // Trace specs parse before any artifact loads (dummy paths are fine);
    // every malformed spec must surface the grammar, typed, on stderr.
    for bad in ["warp:100", "bursty", "bursty:-5", "bursty:0@2", "pareto:10@x"] {
        let out = bin()
            .args([
                "serve", "--model", "/nonexistent.cnq", "--eval", "/nonexistent.npt",
                "--trace", bad,
            ])
            .output()
            .unwrap();
        assert!(!out.status.success(), "accepted malformed trace `{bad}`");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("--trace"), "spec `{bad}`: {err}");
        assert!(err.contains("constant|bursty|diurnal|pareto"), "spec `{bad}`: {err}");
    }
}

#[test]
fn serve_rejects_nonpositive_slo() {
    for bad in ["0", "-3", "inf"] {
        let out = bin()
            .args([
                "serve", "--model", "/nonexistent.cnq", "--eval", "/nonexistent.npt",
                "--slo-ms", bad,
            ])
            .output()
            .unwrap();
        assert!(!out.status.success(), "accepted --slo-ms {bad}");
        assert!(String::from_utf8_lossy(&out.stderr).contains("--slo-ms"), "--slo-ms {bad}");
    }
}

#[test]
fn serve_runs_overload_scenario_on_artifacts_when_present() {
    // Compose the whole robustness surface: a bursty overload trace, a
    // tight SLO, and a board death — the report must show the deadline
    // accounting instead of panicking or serving late.
    if !std::path::Path::new("artifacts/models/mnist.cnq").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let out = bin()
        .args([
            "serve", "--model", "artifacts/models/mnist.cnq",
            "--eval", "artifacts/data/mnist_eval.npt",
            "--n", "16", "--batch", "4",
            "--trace", "bursty:2000@7", "--slo-ms", "5",
            "--inject-faults", "die:0@1",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("trace: bursty at 2000"), "trace line missing:\n{text}");
    assert!(text.contains("slo 5.00 ms"), "deadline accounting missing:\n{text}");
    assert!(text.contains("goodput"), "goodput missing:\n{text}");
}

#[test]
fn serve_runs_with_fault_injection_on_artifacts_when_present() {
    if !std::path::Path::new("artifacts/models/mnist.cnq").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let out = bin()
        .args([
            "serve", "--model", "artifacts/models/mnist.cnq",
            "--eval", "artifacts/data/mnist_eval.npt",
            "--n", "8", "--batch", "2", "--inject-faults", "die:0@1",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("served"), "{text}");
    assert!(text.contains("faults:"), "fault counters missing from report:\n{text}");
}

#[test]
fn serve_trace_out_writes_a_chrome_trace_on_artifacts_when_present() {
    if !std::path::Path::new("artifacts/models/mnist.cnq").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let path = std::env::temp_dir().join("capsnet_cli_smoke_trace.json");
    let _ = std::fs::remove_file(&path);
    let out = bin()
        .args([
            "serve", "--model", "artifacts/models/mnist.cnq",
            "--eval", "artifacts/data/mnist_eval.npt",
            "--n", "16", "--batch", "4",
            "--trace", "bursty:2000@7", "--slo-ms", "5",
            "--inject-faults", "die:0@1", "--trace-out",
        ])
        .arg(&path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("wrote"), "trace export line missing:\n{text}");
    let json = std::fs::read_to_string(&path).expect("trace artifact written");
    assert!(json.contains("\"traceEvents\""), "{json}");
    assert!(json.contains("\"ph\""), "no events emitted:\n{json}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn profile_prints_a_layer_cycle_table_on_artifacts_when_present() {
    if !std::path::Path::new("artifacts/models/mnist.cnq").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    // Batch 1: GAP-8's 512 KB holds the mnist batch-1 arena but not
    // batch 2 — larger batches are the typed-rejection case below.
    let out = bin()
        .args([
            "profile", "--model", "artifacts/models/mnist.cnq",
            "--board", "gap8", "--batch", "1", "--top", "3",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("GAPuino"), "board header missing:\n{text}");
    assert!(text.contains("cycles"), "cycle table missing:\n{text}");
    assert!(text.contains("top 3 spans"), "span report missing:\n{text}");
}

#[test]
fn profile_rejects_batch_arena_exceeding_board_ram() {
    // A profile is a deployment rehearsal: a batch whose interpreter arena
    // cannot fit the board's usable RAM must fail typed before lowering,
    // instead of printing a cycle table for a configuration the board
    // cannot run (or panicking partway through).
    if !std::path::Path::new("artifacts/models/mnist.cnq").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let out = bin()
        .args([
            "profile", "--model", "artifacts/models/mnist.cnq",
            "--board", "gap8", "--batch", "8",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "batch-8 mnist cannot fit GAP-8's 512 KB");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("arena bytes"), "untyped failure: {err}");
    assert!(err.contains("--batch"), "error must point at the flag: {err}");
}

#[test]
fn serve_rejects_readonly_trace_out_file() {
    // An existing file without write permission must fail fast and typed —
    // before the model loads — not at export time after a full serving run.
    let path = std::env::temp_dir().join("capsnet_cli_smoke_readonly_trace.json");
    std::fs::write(&path, "sentinel").unwrap();
    let mut perm = std::fs::metadata(&path).unwrap().permissions();
    perm.set_readonly(true);
    std::fs::set_permissions(&path, perm).unwrap();
    // Privileged users (root in CI containers) bypass permission bits; if
    // this process can still write the file, the scenario is unrealizable
    // here — skip rather than assert the wrong thing.
    if std::fs::write(&path, "still writable").is_ok() {
        let mut perm = std::fs::metadata(&path).unwrap().permissions();
        perm.set_readonly(false);
        let _ = std::fs::set_permissions(&path, perm);
        let _ = std::fs::remove_file(&path);
        eprintln!("SKIP: permission bits not enforced for this user");
        return;
    }
    let out = bin()
        .args(["serve", "--model", "/nonexistent.cnq", "--eval", "/nonexistent.npt", "--trace-out"])
        .arg(&path)
        .output()
        .unwrap();
    let mut perm = std::fs::metadata(&path).unwrap().permissions();
    perm.set_readonly(false);
    let _ = std::fs::set_permissions(&path, perm);
    let _ = std::fs::remove_file(&path);
    assert!(!out.status.success(), "readonly --trace-out must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--trace-out"), "error must point at the flag: {err}");
    // The probe failed before artifacts loaded, so the *model* error never
    // appears — proof the failure is the early writability check.
    assert!(!err.contains("/nonexistent.cnq"), "failed too late: {err}");
}

#[test]
fn infer_runs_on_artifacts_when_present() {
    if !std::path::Path::new("artifacts/models/mnist.cnq").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let out = bin()
        .args([
            "infer", "--model", "artifacts/models/mnist.cnq",
            "--eval", "artifacts/data/mnist_eval.npt",
            "--board", "m7", "--n", "8",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("accuracy"), "{text}");
}
