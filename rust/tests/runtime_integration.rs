//! Runtime integration: load the AOT HLO artifacts through PJRT and
//! cross-check against the native Rust engines.
//!
//! * float HLO (JAX graph with Pallas kernels, interpret-lowered) vs the
//!   Rust `FloatCapsNet` engine — allclose;
//! * qsim HLO (Pallas int8 matmul) vs the Rust q7 matmul — bit-exact;
//! * float HLO classification vs the quantized engine — label agreement.
//!
//! Skips gracefully when artifacts are absent.

use capsnet_edge::dataset::EvalSet;
use capsnet_edge::isa::NullMeter;
use capsnet_edge::kernels::matmul::{arm_mat_mult_q7, MatPlacement};
use capsnet_edge::kernels::MatDims;
use capsnet_edge::model::{ArmConv, FloatCapsNet, QuantizedCapsNet};
use capsnet_edge::runtime::Runtime;
use capsnet_edge::testing::assert_allclose;
use capsnet_edge::testing::prop::XorShift;
use std::path::Path;

fn have(p: &str) -> bool {
    let ok = Path::new(p).exists();
    if !ok {
        eprintln!("SKIP: {p} missing (run `make artifacts`)");
    }
    ok
}

#[test]
fn float_hlo_matches_native_float_engine() {
    if !have("artifacts/hlo/mnist_float.hlo.txt")
        || !have("artifacts/models/mnist.f32.npt")
        || !have("artifacts/data/mnist_eval.npt")
    {
        return;
    }
    let mut rt = Runtime::cpu().unwrap();
    rt.load_hlo("artifacts/hlo/mnist_float.hlo.txt").unwrap();
    let module = rt.get("mnist_float").unwrap();
    let native = FloatCapsNet::load("artifacts/models/mnist.f32.npt").unwrap();
    let eval = EvalSet::load("artifacts/data/mnist_eval.npt").unwrap();
    let dims = [eval.h, eval.w, eval.c];
    for i in 0..4 {
        let hlo_out = module.run_f32(&[(eval.image(i), &dims)]).unwrap();
        let native_out = native.forward(eval.image(i));
        assert_allclose(&hlo_out[0], &native_out, 1e-4, 1e-3, &format!("sample {i}"));
    }
}

#[test]
fn qsim_hlo_matches_q7_matmul_bit_exactly() {
    if !have("artifacts/hlo/mnist_qsim.hlo.txt") {
        return;
    }
    let mut rt = Runtime::cpu().unwrap();
    rt.load_hlo("artifacts/hlo/mnist_qsim.hlo.txt").unwrap();
    let module = rt.get("mnist_qsim").unwrap();
    // mnist qsim shape: [out_caps*out_dim=60, in_caps*in_dim=4096] x [4096, 1]
    let dims = MatDims::new(60, 4096, 1);
    let mut rng = XorShift::new(77);
    let w = rng.i8_vec(dims.a_len());
    let u = rng.i8_vec(dims.b_len());
    let hlo_out = module
        .run_i8(&[(&w, &[60, 4096]), (&u, &[4096, 1])])
        .unwrap();
    let mut native = vec![0i8; 60];
    arm_mat_mult_q7(&w, &u, dims, 7, &mut native, MatPlacement::bench(), &mut NullMeter);
    assert_eq!(hlo_out[0], native, "XLA-executed Pallas int8 matmul != rust q7 matmul");
}

#[test]
fn float_hlo_and_quantized_engine_agree_on_labels() {
    if !have("artifacts/hlo/mnist_float.hlo.txt")
        || !have("artifacts/models/mnist.cnq")
        || !have("artifacts/data/mnist_eval.npt")
    {
        return;
    }
    let mut rt = Runtime::cpu().unwrap();
    rt.load_hlo("artifacts/hlo/mnist_float.hlo.txt").unwrap();
    let module = rt.get("mnist_float").unwrap();
    let qnet = QuantizedCapsNet::load("artifacts/models/mnist.cnq").unwrap();
    let eval = EvalSet::load("artifacts/data/mnist_eval.npt").unwrap();
    let dims = [eval.h, eval.w, eval.c];
    let n = 16;
    let mut agree = 0;
    for i in 0..n {
        let caps = &module.run_f32(&[(eval.image(i), &dims)]).unwrap()[0];
        let dim = 6;
        let float_pred = (0..caps.len() / dim)
            .max_by(|&a, &b| {
                let na: f32 = caps[a * dim..(a + 1) * dim].iter().map(|x| x * x).sum();
                let nb: f32 = caps[b * dim..(b + 1) * dim].iter().map(|x| x * x).sum();
                na.partial_cmp(&nb).unwrap()
            })
            .unwrap();
        let q = qnet.quantize_input(eval.image(i));
        let qout = qnet.forward_arm(&q, ArmConv::FastWithFallback, &mut NullMeter);
        if qnet.classify(&qout) == float_pred {
            agree += 1;
        }
    }
    assert!(
        agree as f64 / n as f64 >= 0.85,
        "float-HLO vs int8 label agreement only {agree}/{n}"
    );
}

#[test]
fn runtime_load_dir_finds_all_artifacts() {
    if !have("artifacts/hlo") {
        return;
    }
    let mut rt = Runtime::cpu().unwrap();
    let names = rt.load_dir("artifacts/hlo").unwrap();
    assert!(!names.is_empty());
    for n in &names {
        assert!(rt.get(n).is_some());
    }
    assert_eq!(rt.platform().to_lowercase(), "cpu");
}

#[test]
fn runtime_rejects_missing_file() {
    let mut rt = Runtime::cpu().unwrap();
    assert!(rt.load_hlo("artifacts/hlo/nonexistent.hlo.txt").is_err());
}
