//! Cross-ISA golden-vector conformance suite.
//!
//! Pins the two contracts every execution surface must uphold, for every
//! reference config × ISA × {pinned, planned, mixed-split} schedule:
//!
//! 1. **Bit-identity** — batch-1, batched (partial batches included), and
//!    scheduled forwards all compute the identical function. The Arm basic
//!    batch-1 forward is the golden vector; everything else must match it
//!    per image.
//! 2. **Split accounting** — a mixed-split RISC-V schedule is *honored* by
//!    the event meter: each layer runs as its own fork/join section at
//!    exactly the split the schedule declares (section log), cores outside
//!    a layer's split receive no events (enforced by
//!    `ClusterRun::close_section`), and the full forward's per-core event
//!    counts decompose into the sum of the per-layer splits the schedule
//!    declares (layer-isolation property below).
//!
//! This extends `tests/golden_events.rs` to the scheduled paths: that suite
//! pins pinned-vs-legacy per-core counts; the uniform-schedule test here
//! pins scheduled-vs-pinned, so scheduled execution inherits the golden
//! streams transitively.
//!
//! A third, *tolerance-based* tier covers the approximate routing
//! nonlinearities (plan schema v3): a forward with every capsule layer on
//! the division-free kernels must stay within a pinned per-element ε of
//! the exact golden vectors, and — exactly like the exact tier — every
//! approx surface (scalar Arm, scalar split RISC-V, SIMD packed, SIMD
//! fallback, planner-lowered programs) must be **bit-identical to each
//! other**. The exact suite above is untouched: approximation is opt-in
//! per layer, never a change to the exact kernels.

use capsnet_edge::isa::{
    fork_join_cycles, Board, ClusterRun, CostModel, CycleCounter, NullMeter, NUM_EVENTS,
};
use capsnet_edge::kernels::conv::PulpConvStrategy;
use capsnet_edge::model::{configs, ArmConv, PulpLayerExec, QuantizedCapsNet, RiscvSchedule};
use capsnet_edge::plan::{plan_deployment, PlanOptions};
use capsnet_edge::testing::prop::XorShift;

/// A deliberately mixed schedule: strategies cycle through all three PULP
/// variants and core splits through {8, 4, 2, 1} — every layer differs from
/// its neighbours in at least one dimension.
fn mixed_schedule(net: &QuantizedCapsNet) -> RiscvSchedule {
    use PulpConvStrategy as S;
    RiscvSchedule {
        conv: (0..net.convs.len() + 1)
            .map(|i| PulpLayerExec {
                strategy: [S::HoWo, S::Co, S::Ho][i % 3],
                cores: [8usize, 4, 2, 1][i % 4],
            })
            .collect(),
        caps: (0..net.caps.len()).map(|i| [4usize, 1, 8, 2][i % 4]).collect(),
    }
}

fn mixed_arm_schedule(net: &QuantizedCapsNet) -> Vec<ArmConv> {
    (0..net.convs.len() + 1)
        .map(|i| if i % 2 == 0 { ArmConv::Basic } else { ArmConv::FastWithFallback })
        .collect()
}

#[test]
fn every_schedule_and_isa_is_bit_identical_per_image() {
    for cfg in configs::all() {
        let name = cfg.name.clone();
        let net = QuantizedCapsNet::random(cfg.clone(), 0xC0);
        let mut rng = XorShift::new(0xC1);
        let in_len = net.config.input_len();
        let out_len = net.config.output_len();
        let capacity = 4usize;
        let batch = 3usize; // partial batch in a capacity-4 arena
        let inputs = rng.i8_vec(batch * in_len);

        // Golden vectors: Arm basic, batch 1, per image.
        let mut golden = vec![0i8; batch * out_len];
        let mut ws1 = net.config.workspace();
        for img in 0..batch {
            net.forward_arm_into(
                &inputs[img * in_len..(img + 1) * in_len],
                ArmConv::Basic,
                &mut ws1,
                &mut golden[img * out_len..(img + 1) * out_len],
                &mut NullMeter,
            );
        }

        let mut ws = net.config.workspace_batched(capacity);
        let mut out = vec![0i8; batch * out_len];
        let check = |label: &str, out: &[i8]| {
            assert_eq!(out, &golden[..], "{name}: {label} diverged from golden vectors");
        };

        // Arm: fast, batched, scheduled, scheduled-batched, planned.
        net.forward_arm_batched_into(
            &inputs, batch, ArmConv::FastWithFallback, &mut ws, &mut out, &mut NullMeter,
        );
        check("arm fast batched", &out);
        let asched = mixed_arm_schedule(&net);
        let mut o1 = vec![0i8; out_len];
        for img in 0..batch {
            net.forward_arm_scheduled_into(
                &inputs[img * in_len..(img + 1) * in_len],
                &asched,
                &mut ws,
                &mut o1,
                &mut NullMeter,
            );
            assert_eq!(o1, golden[img * out_len..(img + 1) * out_len], "{name}: arm scheduled");
        }
        net.forward_arm_scheduled_batched_into(
            &inputs, batch, &asched, &mut ws, &mut out, &mut NullMeter,
        );
        check("arm scheduled batched", &out);
        let arm_plan =
            plan_deployment(&cfg, &capsnet_edge::isa::Board::stm32h755(), &PlanOptions::default());
        net.forward_arm_scheduled_batched_into(
            &inputs, batch, &arm_plan.arm_schedule().unwrap(), &mut ws, &mut out, &mut NullMeter,
        );
        check("arm planned batched", &out);

        // RISC-V: pinned strategies × cluster sizes, batched.
        let model = CostModel::gap8_cluster_core();
        for strat in [PulpConvStrategy::Co, PulpConvStrategy::Ho, PulpConvStrategy::HoWo] {
            for cores in [1usize, 8] {
                let mut run = ClusterRun::new(&model, cores);
                net.forward_riscv_batched_into(&inputs, batch, strat, &mut ws, &mut out, &mut run);
                check(&format!("riscv {strat:?} x{cores} batched"), &out);
            }
        }

        // RISC-V: mixed-split schedule, batch-1 and batched.
        let rsched = mixed_schedule(&net);
        let mut run = ClusterRun::new(&model, 8);
        for img in 0..batch {
            run.reset();
            net.forward_riscv_scheduled_into(
                &inputs[img * in_len..(img + 1) * in_len],
                &rsched,
                &mut ws,
                &mut o1,
                &mut run,
            );
            assert_eq!(
                o1,
                golden[img * out_len..(img + 1) * out_len],
                "{name}: riscv mixed-split"
            );
        }
        run.reset();
        net.forward_riscv_scheduled_batched_into(
            &inputs, batch, &rsched, &mut ws, &mut out, &mut run,
        );
        check("riscv mixed-split batched", &out);

        // RISC-V: planner-derived schedules, mixed and uniform.
        let gap8 = capsnet_edge::isa::Board::gapuino();
        for opts in [
            PlanOptions::default(),
            PlanOptions { mixed_splits: false, ..PlanOptions::default() },
        ] {
            let plan = plan_deployment(&cfg, &gap8, &opts);
            let sched = plan.riscv_schedule().unwrap();
            run.reset();
            net.forward_riscv_scheduled_batched_into(
                &inputs, batch, &sched, &mut ws, &mut out, &mut run,
            );
            check(
                &format!("riscv planned batched (mixed_splits={})", opts.mixed_splits),
                &out,
            );
        }
    }
}

#[test]
fn simd_backend_is_bit_identical_to_scalar_backends_for_every_program() {
    // simd-vs-scalar tier: the vectorized host backend must compute
    // exactly the function the instrumented scalar backends compute — for
    // every reference config × ISA × {uniform, mixed, planned} schedule,
    // through batch-1 and partial-tail batched interpretation, and on the
    // `supported() == false` path too: without a detected vector ISA (or
    // without the `simd` feature at all) the packed-GEMM path runs its
    // scalar dot kernel, and a pool-less backend falls back to the classic
    // scalar kernels — neither may change a single output bit. The suite
    // runs under both feature configurations in CI.
    use capsnet_edge::exec::{self, Program, SimdBackend};
    // Detection must be callable regardless of outcome; either answer is
    // valid depending on the build/host.
    let _ = SimdBackend::supported();
    for cfg in configs::all() {
        let name = cfg.name.clone();
        let net = QuantizedCapsNet::random(cfg, 0xA5);
        let mut rng = XorShift::new(0xA6);
        let in_len = net.config.input_len();
        let out_len = net.config.output_len();
        let capacity = 4usize;
        let batch = 3usize; // partial tail batch in a capacity-4 arena
        let inputs = rng.i8_vec(batch * in_len);
        let mut ws = net.config.workspace_batched(capacity);
        let mut scalar_out = vec![0i8; batch * out_len];
        let mut out = vec![0i8; batch * out_len];
        let mut o1 = vec![0i8; out_len];

        let programs: Vec<(&str, Program)> = vec![
            ("arm basic", Program::lower_arm_uniform(&net, ArmConv::Basic, capacity)),
            ("arm mixed", Program::lower_arm(&net, &mixed_arm_schedule(&net), capacity)),
            (
                "riscv howo x8",
                Program::lower_riscv_uniform(&net, PulpConvStrategy::HoWo, 8, capacity),
            ),
            ("riscv mixed", Program::lower_riscv(&net, &mixed_schedule(&net), capacity)),
            // Plan-lowered programs: what `Fleet::serve_pooled` workers and
            // the calibrator actually interpret. The planner prices through
            // the same `KernelBackend` seam the backends execute through, so
            // its chosen schedules must survive the swap bit-for-bit too.
            (
                "arm planned",
                Program::lower_plan(
                    &net,
                    &plan_deployment(&net.config, &Board::stm32h755(), &PlanOptions::default()),
                    capacity,
                )
                .unwrap(),
            ),
            (
                "riscv planned",
                Program::lower_plan(
                    &net,
                    &plan_deployment(&net.config, &Board::gapuino(), &PlanOptions::default()),
                    capacity,
                )
                .unwrap(),
            ),
        ];
        let mut simd = SimdBackend::for_config(&net.config, capacity);
        for (label, prog) in &programs {
            // Scalar reference: the program through its own metered stack.
            if prog.isa() == exec::ProgramIsa::Arm {
                let mut meter = NullMeter;
                let mut backend = exec::ArmBackend::new(&mut meter);
                exec::run_program_batched(
                    &net, prog, &inputs, batch, &mut ws, &mut scalar_out, &mut backend,
                );
            } else {
                let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
                let mut backend = exec::PulpBackend::new(&mut run);
                exec::run_program_batched(
                    &net, prog, &inputs, batch, &mut ws, &mut scalar_out, &mut backend,
                );
            }

            // Packed-GEMM path, batched.
            exec::run_program_batched(&net, prog, &inputs, batch, &mut ws, &mut out, &mut simd);
            assert_eq!(out, scalar_out, "{name}: {label}: simd batched diverged");

            // Packed-GEMM path, batch 1 per image through the same program.
            for img in 0..batch {
                exec::run_program(
                    &net,
                    prog,
                    &inputs[img * in_len..(img + 1) * in_len],
                    &mut ws,
                    &mut o1,
                    &mut simd,
                );
                assert_eq!(
                    o1,
                    scalar_out[img * out_len..(img + 1) * out_len],
                    "{name}: {label}: simd batch-1 image {img} diverged"
                );
            }

            // Pool-less backend: every layer misses the packing pool and
            // falls back to the classic scalar kernels.
            let mut fallback = SimdBackend::new();
            exec::run_program_batched(
                &net, prog, &inputs, batch, &mut ws, &mut out, &mut fallback,
            );
            assert_eq!(out, scalar_out, "{name}: {label}: pool-less fallback diverged");
        }
    }
}

#[test]
fn mixed_split_sections_match_declared_schedule() {
    // Executing a mixed-split schedule must produce exactly one meter
    // section per layer, at exactly the declared split, and the cluster
    // total must be the sum of per-section maxima + per-split fork/joins —
    // the "meter sees the exact per-layer cluster configuration" criterion.
    for cfg in configs::all() {
        let name = cfg.name.clone();
        let net = QuantizedCapsNet::random(cfg, 0xD0);
        let mut rng = XorShift::new(0xD1);
        let input = rng.i8_vec(net.config.input_len());
        let sched = mixed_schedule(&net);
        let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
        run.enable_section_log();
        let mut ws = net.config.workspace();
        let mut out = vec![0i8; net.config.output_len()];
        net.forward_riscv_scheduled_into(&input, &sched, &mut ws, &mut out, &mut run);
        let declared: Vec<usize> = sched.splits().collect();
        let metered: Vec<usize> = run.sections().iter().map(|s| s.split).collect();
        assert_eq!(metered, declared, "{name}: sections differ from declared splits");
        let total: u64 = run
            .sections()
            .iter()
            .map(|s| s.max_cycles + fork_join_cycles(s.split))
            .sum();
        assert_eq!(run.cycles(), total, "{name}: cluster total != sum of sections");
    }
}

/// Per-core, per-event counts of a full scheduled forward.
fn counts_of(
    net: &QuantizedCapsNet,
    input: &[i8],
    sched: &RiscvSchedule,
) -> Vec<[u64; NUM_EVENTS]> {
    let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
    let mut ws = net.config.workspace();
    let mut out = vec![0i8; net.config.output_len()];
    net.forward_riscv_scheduled_into(input, sched, &mut ws, &mut out, &mut run);
    run.cores.iter().map(|c| *c.counts()).collect()
}

#[test]
fn mixed_split_event_counts_equal_sum_of_per_layer_splits() {
    // Layer-isolation property: a layer's per-core event stream depends
    // only on its own input activations (identical across schedules — all
    // splits compute the same function) and its own split. So for the
    // mixed schedule S with L layers, and S_ℓ = "layer ℓ at its S-split,
    // every other layer on 1 core":
    //
    //   cores c ≥ 1:  counts_S[c]  == Σ_ℓ counts_{S_ℓ}[c]
    //   core  c == 0: counts_S[0]  == Σ_ℓ counts_{S_ℓ}[0]
    //                                 − (L−1) · counts_{all-1-core}[0]
    //
    // (single-core layers run entirely on core 0, so each S_ℓ adds all
    // other layers' full streams there, over-counting L−1 single-core
    // passes). This is the strongest form of "per-core event counts for a
    // mixed-split schedule equal the sum of the per-layer splits the plan
    // declares": it is exact, per event kind, on live data.
    let cfg = configs::cifar10();
    let net = QuantizedCapsNet::random(cfg, 0xE0);
    let mut rng = XorShift::new(0xE1);
    let input = rng.i8_vec(net.config.input_len());
    let mixed = mixed_schedule(&net);
    let n_layers = mixed.conv.len() + mixed.caps.len();

    let all_one = RiscvSchedule {
        conv: mixed.conv.iter().map(|l| PulpLayerExec { strategy: l.strategy, cores: 1 }).collect(),
        caps: mixed.caps.iter().map(|_| 1).collect(),
    };
    let full = counts_of(&net, &input, &mixed);
    let base = counts_of(&net, &input, &all_one);

    let mut summed = vec![[0u64; NUM_EVENTS]; 8];
    for layer in 0..n_layers {
        let mut isolated = all_one.clone();
        if layer < mixed.conv.len() {
            isolated.conv[layer].cores = mixed.conv[layer].cores;
        } else {
            isolated.caps[layer - mixed.conv.len()] = mixed.caps[layer - mixed.conv.len()];
        }
        for (core, counts) in counts_of(&net, &input, &isolated).into_iter().enumerate() {
            for (ev, n) in counts.into_iter().enumerate() {
                summed[core][ev] += n;
            }
        }
    }
    for core in 1..8 {
        assert_eq!(full[core], summed[core], "core {core}: mixed counts != per-layer sum");
    }
    for ev in 0..NUM_EVENTS {
        assert_eq!(
            full[0][ev] + (n_layers as u64 - 1) * base[0][ev],
            summed[0][ev],
            "core 0 event {ev}: mixed counts != per-layer sum"
        );
    }
}

#[test]
fn uniform_schedule_matches_pinned_per_core_golden_events() {
    // Scheduled execution with a uniform full-cluster schedule is the
    // pinned path by another name: per-core event counts and cluster
    // cycles must be identical for every strategy — which ties the
    // scheduled paths into `tests/golden_events.rs`' legacy pins.
    let model = CostModel::gap8_cluster_core();
    for cfg in [configs::mnist(), configs::cifar10()] {
        let name = cfg.name.clone();
        let net = QuantizedCapsNet::random(cfg, 0xF0);
        let mut rng = XorShift::new(0xF1);
        let input = rng.i8_vec(net.config.input_len());
        let mut ws = net.config.workspace();
        let mut out = vec![0i8; net.config.output_len()];
        for strat in [PulpConvStrategy::Co, PulpConvStrategy::Ho, PulpConvStrategy::HoWo] {
            for cores in [1usize, 8] {
                let mut pinned = ClusterRun::new(&model, cores);
                net.forward_riscv_into(&input, strat, &mut ws, &mut out, &mut pinned);
                let pinned_out = out.clone();
                let sched =
                    RiscvSchedule::uniform(strat, cores, net.convs.len(), net.caps.len());
                let mut scheduled = ClusterRun::new(&model, cores);
                net.forward_riscv_scheduled_into(&input, &sched, &mut ws, &mut out, &mut scheduled);
                assert_eq!(out, pinned_out, "{name} {strat:?} x{cores}");
                for (c, (a, b)) in pinned.cores.iter().zip(scheduled.cores.iter()).enumerate() {
                    assert_eq!(a.counts(), b.counts(), "{name} {strat:?} x{cores} core {c}");
                }
                assert_eq!(pinned.cycles(), scheduled.cycles(), "{name} {strat:?} x{cores}");
            }
        }
        // Arm side: uniform schedule == pinned, counts included.
        let mut cc_pinned = CycleCounter::new(CostModel::cortex_m7());
        let pinned_out = net.forward_arm(&input, ArmConv::FastWithFallback, &mut cc_pinned);
        let sched = vec![ArmConv::FastWithFallback; net.convs.len() + 1];
        let mut cc_sched = CycleCounter::new(CostModel::cortex_m7());
        net.forward_arm_scheduled_into(&input, &sched, &mut ws, &mut out, &mut cc_sched);
        assert_eq!(out, pinned_out, "{name} arm");
        assert_eq!(cc_pinned.counts(), cc_sched.counts(), "{name} arm counts");
    }
}

/// Per-element tolerance of the approx conformance tier, end to end.
///
/// Budget: the approximate squash underestimates by at most 8 q7 steps
/// (reciprocal + isqrt LUTs, kernel tests pin ε = 8) and the approximate
/// softmax shifts each coupling coefficient by at most 2/127 ≈ 1.6 %,
/// which perturbs the routed prediction vectors by a few percent of full
/// scale across the routing iterations. One capsule layer (every
/// reference config) lands well under half this budget; the doubled
/// headroom keeps the pin meaningful without being brittle.
const APPROX_PROGRAM_EPS: i32 = 32;

#[test]
fn approx_tier_bit_identical_across_backends_and_within_tolerance_of_exact() {
    use capsnet_edge::exec::{self, Nonlinearity, Program, SimdBackend};
    for cfg in configs::all() {
        let name = cfg.name.clone();
        let net = QuantizedCapsNet::random(cfg.clone(), 0xAB);
        let mut rng = XorShift::new(0xAC);
        let in_len = net.config.input_len();
        let out_len = net.config.output_len();
        let capacity = 4usize;
        let batch = 3usize; // partial batch in a capacity-4 arena
        let inputs = rng.i8_vec(batch * in_len);
        let mut ws = net.config.workspace_batched(capacity);

        // Exact golden vectors — the untouched tier-1 contract.
        let mut exact = vec![0i8; batch * out_len];
        {
            let prog = Program::lower_arm_uniform(&net, ArmConv::Basic, capacity);
            let mut meter = NullMeter;
            let mut backend = exec::ArmBackend::new(&mut meter);
            exec::run_program_batched(
                &net, &prog, &inputs, batch, &mut ws, &mut exact, &mut backend,
            );
        }

        // Approx reference: Arm basic with every capsule layer approximate,
        // through the instrumented scalar backend.
        let nl = vec![Nonlinearity::Approx; net.caps.len()];
        let arm_basic = vec![ArmConv::Basic; net.convs.len() + 1];
        let mut approx = vec![0i8; batch * out_len];
        {
            let prog = Program::lower_arm_nl(&net, &arm_basic, &nl, capacity);
            let mut meter = NullMeter;
            let mut backend = exec::ArmBackend::new(&mut meter);
            exec::run_program_batched(
                &net, &prog, &inputs, batch, &mut ws, &mut approx, &mut backend,
            );
        }

        // Tolerance tier: pinned per-element ε against the exact vectors.
        for (i, (&a, &e)) in approx.iter().zip(exact.iter()).enumerate() {
            let d = (a as i32 - e as i32).abs();
            assert!(
                d <= APPROX_PROGRAM_EPS,
                "{name}: element {i}: approx {a} vs exact {e} (|delta| {d} > {APPROX_PROGRAM_EPS})"
            );
        }
        // The approximation must actually engage somewhere, or this tier
        // silently degenerates into a copy of the exact suite.
        assert_ne!(approx, exact, "{name}: approx forward never diverged from exact");

        // Bit-identity *within* the approx tier: every schedule, ISA, and
        // backend computes the same approximate function. Planned programs
        // use a budget that admits approx everywhere (the planner test pins
        // that admission ⇒ selection on these workloads).
        let plan_opts = PlanOptions { accuracy_budget: 1.0, ..PlanOptions::default() };
        let programs: Vec<(&str, Program)> = vec![
            ("arm mixed", Program::lower_arm_nl(&net, &mixed_arm_schedule(&net), &nl, capacity)),
            (
                "riscv howo x8",
                Program::lower_riscv_nl(
                    &net,
                    &RiscvSchedule::uniform(
                        PulpConvStrategy::HoWo,
                        8,
                        net.convs.len(),
                        net.caps.len(),
                    ),
                    &nl,
                    capacity,
                ),
            ),
            ("riscv mixed", Program::lower_riscv_nl(&net, &mixed_schedule(&net), &nl, capacity)),
            (
                "arm planned",
                Program::lower_plan(
                    &net,
                    &plan_deployment(&net.config, &Board::stm32h755(), &plan_opts),
                    capacity,
                )
                .unwrap(),
            ),
            (
                "riscv planned",
                Program::lower_plan(
                    &net,
                    &plan_deployment(&net.config, &Board::gapuino(), &plan_opts),
                    capacity,
                )
                .unwrap(),
            ),
        ];
        let mut out = vec![0i8; batch * out_len];
        let mut o1 = vec![0i8; out_len];
        let mut simd = SimdBackend::for_config(&net.config, capacity);
        for (label, prog) in &programs {
            if prog.isa() == exec::ProgramIsa::Arm {
                let mut meter = NullMeter;
                let mut backend = exec::ArmBackend::new(&mut meter);
                exec::run_program_batched(
                    &net, prog, &inputs, batch, &mut ws, &mut out, &mut backend,
                );
            } else {
                let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
                let mut backend = exec::PulpBackend::new(&mut run);
                exec::run_program_batched(
                    &net, prog, &inputs, batch, &mut ws, &mut out, &mut backend,
                );
            }
            assert_eq!(out, approx, "{name}: {label}: scalar approx diverged from reference");

            exec::run_program_batched(&net, prog, &inputs, batch, &mut ws, &mut out, &mut simd);
            assert_eq!(out, approx, "{name}: {label}: simd batched diverged");
            for img in 0..batch {
                exec::run_program(
                    &net,
                    prog,
                    &inputs[img * in_len..(img + 1) * in_len],
                    &mut ws,
                    &mut o1,
                    &mut simd,
                );
                assert_eq!(
                    o1,
                    approx[img * out_len..(img + 1) * out_len],
                    "{name}: {label}: simd batch-1 image {img} diverged"
                );
            }
            let mut fallback = SimdBackend::new();
            exec::run_program_batched(&net, prog, &inputs, batch, &mut ws, &mut out, &mut fallback);
            assert_eq!(out, approx, "{name}: {label}: pool-less fallback diverged");
        }
    }
}
