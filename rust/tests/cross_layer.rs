//! Cross-layer integration tests: the Rust kernels must be bit-identical to
//! the Python `qmath` oracles (DESIGN.md §7 contract), verified via the
//! exported test vectors, and the quantized engine must agree end-to-end
//! with the Python int-simulation on real model artifacts.
//!
//! Requires `make artifacts` (skips gracefully when artifacts are absent so
//! `cargo test` stays green on a fresh checkout).

use capsnet_edge::formats::Archive;
use capsnet_edge::isa::NullMeter;
use capsnet_edge::kernels::capsule::{capsule_layer_q7_arm, CapsuleDims, CapsuleShifts};
use capsnet_edge::kernels::conv::{arm_convolve_hwc_q7_basic, ConvDims};
use capsnet_edge::kernels::matmul::{arm_mat_mult_q7, MatPlacement};
use capsnet_edge::kernels::softmax::softmax_q7;
use capsnet_edge::kernels::squash::{squash_q7, SquashParams};
use capsnet_edge::kernels::MatDims;
use capsnet_edge::model::{ArmConv, QuantizedCapsNet};
use std::path::{Path, PathBuf};

fn vectors_dir() -> Option<PathBuf> {
    let p = Path::new("artifacts/testvectors");
    p.exists().then(|| p.to_path_buf())
}

fn load(name: &str) -> Option<Archive> {
    let dir = vectors_dir()?;
    let path = dir.join(name);
    if !path.exists() {
        eprintln!("SKIP: {} missing (run `make artifacts`)", path.display());
        return None;
    }
    Some(Archive::load(path).expect("loading vector archive"))
}

fn count(a: &Archive) -> usize {
    a.req("count").unwrap().scalar_i32().unwrap() as usize
}

#[test]
fn matmul_matches_python_bit_exactly() {
    let Some(a) = load("matmul.npt") else { return };
    for i in 0..count(&a) {
        let ta = a.req(&format!("case{i}.a")).unwrap();
        let tb = a.req(&format!("case{i}.b")).unwrap();
        let dims = MatDims::new(ta.dims()[0], ta.dims()[1], tb.dims()[1]);
        let shift = a.req(&format!("case{i}.shift")).unwrap().scalar_i32().unwrap() as u32;
        let expected = a.req(&format!("case{i}.out")).unwrap().as_i8().unwrap();
        let mut out = vec![0i8; dims.out_len()];
        arm_mat_mult_q7(
            ta.as_i8().unwrap(),
            tb.as_i8().unwrap(),
            dims,
            shift,
            &mut out,
            MatPlacement::bench(),
            &mut NullMeter,
        );
        assert_eq!(out.as_slice(), expected, "matmul case {i}");
    }
}

#[test]
fn squash_matches_python_bit_exactly() {
    let Some(a) = load("squash.npt") else { return };
    for i in 0..count(&a) {
        let tx = a.req(&format!("case{i}.x")).unwrap();
        let (n, d) = (tx.dims()[0], tx.dims()[1]);
        let qn = a.req(&format!("case{i}.in_qn")).unwrap().scalar_i32().unwrap();
        let expected = a.req(&format!("case{i}.out")).unwrap().as_i8().unwrap();
        let mut data = tx.as_i8().unwrap().to_vec();
        squash_q7(&mut data, n, d, SquashParams::q7_out(qn), &mut NullMeter);
        assert_eq!(data.as_slice(), expected, "squash case {i}");
    }
}

#[test]
fn softmax_matches_python_bit_exactly() {
    let Some(a) = load("softmax.npt") else { return };
    for i in 0..count(&a) {
        let tx = a.req(&format!("case{i}.x")).unwrap();
        let (rows, n) = (tx.dims()[0], tx.dims()[1]);
        let expected = a.req(&format!("case{i}.out")).unwrap().as_i8().unwrap();
        let x = tx.as_i8().unwrap();
        let mut out = vec![0i8; rows * n];
        for r in 0..rows {
            softmax_q7(&x[r * n..(r + 1) * n], &mut out[r * n..(r + 1) * n], &mut NullMeter);
        }
        assert_eq!(out.as_slice(), expected, "softmax case {i}");
    }
}

#[test]
fn conv_matches_python_bit_exactly() {
    let Some(a) = load("conv.npt") else { return };
    for i in 0..count(&a) {
        let p = a.req(&format!("case{i}.params")).unwrap().as_i32().unwrap().to_vec();
        let (ih, iw, ic, oc, k, s, pad, bs, os, relu) = (
            p[0] as usize, p[1] as usize, p[2] as usize, p[3] as usize, p[4] as usize,
            p[5] as usize, p[6] as usize, p[7] as u32, p[8] as u32, p[9] != 0,
        );
        let d = ConvDims { in_h: ih, in_w: iw, in_ch: ic, out_ch: oc, k_h: k, k_w: k, stride: s, pad };
        let x = a.req(&format!("case{i}.x")).unwrap().as_i8().unwrap();
        let w = a.req(&format!("case{i}.w")).unwrap().as_i8().unwrap();
        let b = a.req(&format!("case{i}.b")).unwrap().as_i8().unwrap();
        let expected = a.req(&format!("case{i}.out")).unwrap().as_i8().unwrap();
        let mut out = vec![0i8; d.out_len()];
        arm_convolve_hwc_q7_basic(x, w, b, &d, bs, os, relu, &mut out, &mut NullMeter);
        assert_eq!(out.as_slice(), expected, "conv case {i}");
    }
}

#[test]
fn capsule_layer_matches_python_bit_exactly() {
    let Some(a) = load("capsule.npt") else { return };
    for i in 0..count(&a) {
        let dims_v = a.req(&format!("case{i}.dims")).unwrap().as_i32().unwrap().to_vec();
        let (oc, ic, od, idim, r, ih_shift) = (
            dims_v[0] as usize, dims_v[1] as usize, dims_v[2] as usize,
            dims_v[3] as usize, dims_v[4] as usize, dims_v[5] as u32,
        );
        let d = CapsuleDims::new(oc, ic, od, idim);
        let u = a.req(&format!("case{i}.u")).unwrap().as_i8().unwrap();
        let w = a.req(&format!("case{i}.w")).unwrap().as_i8().unwrap();
        let to_u32 = |name: &str| -> Vec<u32> {
            a.req(name).unwrap().as_i32().unwrap().iter().map(|&v| v as u32).collect()
        };
        let shifts = CapsuleShifts {
            inputs_hat: ih_shift,
            caps_out: to_u32(&format!("case{i}.caps_out_shifts")),
            squash_in_qn: a
                .req(&format!("case{i}.squash_in_qns"))
                .unwrap()
                .as_i32()
                .unwrap()
                .to_vec(),
            agreement: to_u32(&format!("case{i}.agreement_shifts")),
            logit_acc: to_u32(&format!("case{i}.logit_acc_shifts")),
        };
        let expected = a.req(&format!("case{i}.out")).unwrap().as_i8().unwrap();
        let mut out = vec![0i8; d.output_len()];
        capsule_layer_q7_arm(u, w, &d, r, &shifts, &mut out, &mut NullMeter);
        assert_eq!(out.as_slice(), expected, "capsule case {i}");
    }
}

#[test]
fn full_model_matches_python_engine() {
    // Full quantized MNIST net: rust engine vs python int-sim on real eval
    // images — every layer, every shift, bit for bit.
    let Some(a) = load("model_mnist.npt") else { return };
    let model_path = Path::new("artifacts/models/mnist.cnq");
    if !model_path.exists() {
        eprintln!("SKIP: mnist.cnq missing");
        return;
    }
    let net = QuantizedCapsNet::load(model_path).unwrap();
    let n = count(&a);
    let inputs = a.req("input_q").unwrap();
    let expected = a.req("expected").unwrap();
    let in_len = inputs.dims()[1];
    let out_len = expected.dims()[1];
    let iq = inputs.as_i8().unwrap();
    let eq = expected.as_i8().unwrap();
    for i in 0..n {
        let out = net.forward_arm(&iq[i * in_len..(i + 1) * in_len], ArmConv::Basic, &mut NullMeter);
        assert_eq!(
            out.as_slice(),
            &eq[i * out_len..(i + 1) * out_len],
            "model forward sample {i}"
        );
    }
}

#[test]
fn quantized_model_accuracy_on_eval_set() {
    // Table-2 style accuracy check through the Rust engine.
    let model_path = Path::new("artifacts/models/mnist.cnq");
    let eval_path = Path::new("artifacts/data/mnist_eval.npt");
    if !model_path.exists() || !eval_path.exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let net = QuantizedCapsNet::load(model_path).unwrap();
    let eval = capsnet_edge::dataset::EvalSet::load(eval_path).unwrap();
    let n = 64.min(eval.len());
    let mut correct = 0;
    for i in 0..n {
        let q = net.quantize_input(eval.image(i));
        let out = net.forward_arm(&q, ArmConv::FastWithFallback, &mut NullMeter);
        if net.classify(&out) == eval.labels[i] as usize {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.9, "rust int8 accuracy only {acc:.3} on {n} samples");
}
