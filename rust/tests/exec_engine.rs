//! Execution-engine contracts: program lowering vs. the deployment memory
//! map, and plan-lowered interpretation vs. the scheduled forward wrappers.
//!
//! 1. **Offset agreement** — a [`Program`]'s precomputed arena layout
//!    (ping/pong activation slabs + kernel scratch) must equal the
//!    [`MemoryMap`] regions a deployment plan serializes, for every
//!    reference *and* random config × batch capacity × ISA. The interpreter
//!    carves exactly these offsets, so this pins "the engine runs inside
//!    the arena the plan declared".
//! 2. **Plan-lowering identity** — lowering a v2 `DeploymentPlan` once at
//!    capacity and interpreting it is bit-for-bit identical to
//!    `forward_riscv_scheduled_batched_into` (which lowers per call at
//!    batch stride — independent lowering parameters), with identical
//!    per-core event counts and cluster cycles; golden-vector bit-identity
//!    of both paths is pinned by `tests/conformance.rs`.

use capsnet_edge::exec::{run_program, run_program_batched, ArmBackend, Program, PulpBackend};
use capsnet_edge::isa::{Board, ClusterRun, CostModel, NullMeter};
use capsnet_edge::kernels::conv::PulpConvStrategy;
use capsnet_edge::model::{configs, ArmConv, CapsNetConfig, QuantizedCapsNet};
use capsnet_edge::plan::{plan_deployment, MemoryMap, PlanOptions};
use capsnet_edge::testing::prop::{rand_config, Prop, XorShift};

/// Assert one lowered program's layout against the plan memory map for the
/// same (config, capacity).
fn check_layout(cfg: &CapsNetConfig, prog: &Program, capacity: usize, label: &str) {
    let regions = MemoryMap::arena_regions(cfg, capacity);
    let l = prog.arena_layout();
    assert_eq!(regions.len(), 3, "{label}: unexpected region count");
    assert_eq!(regions[0].name, "act_ping");
    assert_eq!(regions[1].name, "act_pong");
    assert_eq!(regions[2].name, "kernel_scratch");
    assert_eq!(regions[0].offset, l.act_ping_offset, "{label}: ping offset");
    assert_eq!(regions[1].offset, l.act_pong_offset, "{label}: pong offset");
    assert_eq!(regions[2].offset, l.kernel_scratch_offset, "{label}: kscratch offset");
    assert_eq!(regions[0].bytes, l.act_bytes, "{label}: ping bytes");
    assert_eq!(regions[1].bytes, l.act_bytes, "{label}: pong bytes");
    assert_eq!(regions[2].bytes, l.kernel_scratch_bytes, "{label}: kscratch bytes");
    assert_eq!(l.arena_bytes, cfg.scratch_i8_len_batched(capacity), "{label}: arena total");
    // The map a plan actually serializes derives from the same regions.
    let map = MemoryMap::for_deployment(cfg, &Board::gapuino(), capacity);
    assert_eq!(map.regions, regions, "{label}: for_deployment drifted from arena_regions");
    assert_eq!(map.arena_bytes, l.arena_bytes, "{label}: map arena total");
}

#[test]
fn program_offsets_match_memory_map_for_every_config_and_capacity() {
    for cfg in configs::all() {
        let net = QuantizedCapsNet::random(cfg.clone(), 0xA0);
        for capacity in [1usize, 2, 4, 8] {
            let arm = Program::lower_arm_uniform(&net, ArmConv::FastWithFallback, capacity);
            check_layout(&cfg, &arm, capacity, &format!("{} arm x{capacity}", cfg.name));
            let rv = Program::lower_riscv_uniform(&net, PulpConvStrategy::HoWo, 8, capacity);
            check_layout(&cfg, &rv, capacity, &format!("{} riscv x{capacity}", cfg.name));
        }
    }
}

#[test]
fn program_offsets_match_memory_map_for_random_configs() {
    // Property form of the satellite: arbitrary architectures × batch
    // capacities agree between lowering and the plan memory map.
    Prop::new("program layout == MemoryMap regions", 25).run(|rng| {
        let cfg = rand_config(rng);
        let net = QuantizedCapsNet::random(cfg.clone(), rng.next_u64());
        let capacity = rng.range(1, 6);
        let prog = Program::lower_arm_uniform(&net, ArmConv::Basic, capacity);
        check_layout(&cfg, &prog, capacity, &format!("rand x{capacity}"));
    });
}

#[test]
fn plan_lowered_program_equals_scheduled_batched_forward_bit_for_bit() {
    // Satellite: lowering a v2 plan and interpreting it == the scheduled
    // batched wrapper — outputs, per-core event counts, and cluster cycles.
    //
    // Both sides go through the engine (the wrapper lowers per call), but
    // with *independent lowering parameters*: the wrapper lowers at
    // batch-3 stride, the pre-lowered program at capacity-4 stride — so
    // slab placement, partial-batch prefixing, and `lower_plan`'s
    // plan→schedule resolution are all exercised against each other.
    // Absolute bit-identity of both sides to the Arm-basic golden vectors
    // is pinned separately by `tests/conformance.rs`.
    for cfg in configs::all() {
        let name = cfg.name.clone();
        let net = QuantizedCapsNet::random(cfg.clone(), 0xB0);
        let mut rng = XorShift::new(0xB1);
        let capacity = 4usize;
        let batch = 3usize; // partial batch in a capacity-4 arena
        let inputs = rng.i8_vec(batch * net.config.input_len());
        let plan = plan_deployment(
            &cfg,
            &Board::gapuino(),
            &PlanOptions { batch_capacity: capacity, ..PlanOptions::default() },
        );
        let schedule = plan.riscv_schedule().unwrap();
        let model = CostModel::gap8_cluster_core();
        let out_len = net.config.output_len();

        let mut ws = net.config.workspace_batched(capacity);
        let mut expected = vec![0i8; batch * out_len];
        let mut run_ref = ClusterRun::new(&model, 8);
        net.forward_riscv_scheduled_batched_into(
            &inputs, batch, &schedule, &mut ws, &mut expected, &mut run_ref,
        );

        let prog = Program::lower_plan(&net, &plan, capacity).unwrap();
        check_layout(&cfg, &prog, capacity, &format!("{name} plan-lowered"));
        let mut got = vec![0i8; batch * out_len];
        let mut run = ClusterRun::new(&model, 8);
        run_program_batched(
            &net, &prog, &inputs, batch, &mut ws, &mut got, &mut PulpBackend::new(&mut run),
        );
        assert_eq!(got, expected, "{name}: plan-lowered program diverged");
        for (c, (a, b)) in run_ref.cores.iter().zip(run.cores.iter()).enumerate() {
            assert_eq!(a.counts(), b.counts(), "{name}: core {c} event counts");
        }
        assert_eq!(run_ref.cycles(), run.cycles(), "{name}: cluster cycles");
    }
}

#[test]
fn arm_plan_lowering_equals_scheduled_wrapper() {
    let cfg = configs::cifar10();
    let net = QuantizedCapsNet::random(cfg.clone(), 0xB2);
    let mut rng = XorShift::new(0xB3);
    let input = rng.i8_vec(net.config.input_len());
    let plan = plan_deployment(&cfg, &Board::stm32h755(), &PlanOptions::default());
    let mut ws = net.config.workspace();
    let mut expected = vec![0i8; net.config.output_len()];
    net.forward_arm_scheduled_into(
        &input, &plan.arm_schedule().unwrap(), &mut ws, &mut expected, &mut NullMeter,
    );
    let prog = Program::lower_plan(&net, &plan, 1).unwrap();
    let mut got = vec![0i8; net.config.output_len()];
    run_program(&net, &prog, &input, &mut ws, &mut got, &mut ArmBackend::new(&mut NullMeter));
    assert_eq!(got, expected);
}

#[test]
fn capacity_program_serves_every_smaller_batch_identically() {
    // A resident worker lowers at capacity once and runs any batch ≤ it:
    // results must equal per-batch-lowered wrappers (which carve at batch
    // strides, not capacity strides — slab placement must not matter).
    let net = QuantizedCapsNet::random(configs::mnist(), 0xB4);
    let mut rng = XorShift::new(0xB5);
    let capacity = 5usize;
    let in_len = net.config.input_len();
    let out_len = net.config.output_len();
    let prog = Program::lower_arm_uniform(&net, ArmConv::FastWithFallback, capacity);
    let mut ws = net.config.workspace_batched(capacity);
    for batch in 1..=capacity {
        let inputs = rng.i8_vec(batch * in_len);
        let mut expected = vec![0i8; batch * out_len];
        net.forward_arm_batched_into(
            &inputs, batch, ArmConv::FastWithFallback, &mut ws, &mut expected, &mut NullMeter,
        );
        let mut got = vec![0i8; batch * out_len];
        run_program_batched(
            &net, &prog, &inputs, batch, &mut ws, &mut got,
            &mut ArmBackend::new(&mut NullMeter),
        );
        assert_eq!(got, expected, "batch {batch} of capacity {capacity}");
    }
}
