//! Asserts the hard acceptance criterion of the execution engine: zero
//! heap allocations inside the interpreter's inference loop
//! (`exec::run_program` / `exec::run_program_batched`) after program
//! lowering and workspace construction — with request tracing DISABLED
//! and ENABLED (`run_program_batched_traced` records into a preallocated
//! ring). Lowering is a deployment-time operation and *may* allocate;
//! interpretation is the per-request hot path and may not.
//!
//! A counting global allocator (installed for this test binary only)
//! tallies allocations per thread; interpreting a pre-lowered program must
//! leave the tally untouched. Per-thread counting keeps the assertion
//! immune to the test harness running other tests concurrently.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        // try_with: TLS may be unavailable during thread teardown.
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(p, l, new_size)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

use capsnet_edge::exec::{run_program, run_program_batched, ArmBackend, Program, PulpBackend};
use capsnet_edge::isa::{ClusterRun, CostModel, CycleCounter, NullMeter};
use capsnet_edge::kernels::conv::PulpConvStrategy;
use capsnet_edge::model::{configs, ArmConv, QuantizedCapsNet};
use capsnet_edge::testing::prop::XorShift;

#[test]
fn arm_program_interpretation_is_allocation_free() {
    for cfg in [configs::mnist(), configs::cifar10()] {
        let name = cfg.name.clone();
        let net = QuantizedCapsNet::random(cfg, 42);
        let mut rng = XorShift::new(1);
        let input = rng.i8_vec(net.config.input_len());
        let mut ws = net.config.workspace();
        let mut out = vec![0i8; net.config.output_len()];
        for conv in [ArmConv::Basic, ArmConv::FastWithFallback] {
            // Lower once (deployment time — may allocate) ...
            let prog = Program::lower_arm_uniform(&net, conv, 1);
            // ... warm-up pass (pages, lazily-initialized statics) ...
            let mut meter = NullMeter;
            run_program(&net, &prog, &input, &mut ws, &mut out, &mut ArmBackend::new(&mut meter));
            let before = thread_allocs();
            // ... then the interpreter loop must not touch the heap.
            run_program(&net, &prog, &input, &mut ws, &mut out, &mut ArmBackend::new(&mut meter));
            let after = thread_allocs();
            assert_eq!(
                after - before,
                0,
                "{name} {conv:?}: run_program heap-allocated {} time(s)",
                after - before
            );
        }
    }
}

#[test]
fn metered_program_interpretation_is_allocation_free() {
    // The fleet latency simulator runs the same interpreter with a
    // CycleCounter — metering must not introduce allocations either.
    let net = QuantizedCapsNet::random(configs::mnist(), 7);
    let mut rng = XorShift::new(2);
    let input = rng.i8_vec(net.config.input_len());
    let mut ws = net.config.workspace();
    let mut out = vec![0i8; net.config.output_len()];
    let prog = Program::lower_arm_uniform(&net, ArmConv::FastWithFallback, 1);
    let mut cc = CycleCounter::new(CostModel::cortex_m4());
    run_program(&net, &prog, &input, &mut ws, &mut out, &mut ArmBackend::new(&mut cc));
    let before = thread_allocs();
    run_program(&net, &prog, &input, &mut ws, &mut out, &mut ArmBackend::new(&mut cc));
    assert_eq!(thread_allocs() - before, 0, "metered run_program allocated");
}

#[test]
fn riscv_program_interpretation_is_allocation_free() {
    let net = QuantizedCapsNet::random(configs::cifar10(), 42);
    let mut rng = XorShift::new(3);
    let input = rng.i8_vec(net.config.input_len());
    let mut ws = net.config.workspace();
    let mut out = vec![0i8; net.config.output_len()];
    for cores in [1usize, 8] {
        for strategy in [PulpConvStrategy::Co, PulpConvStrategy::Ho, PulpConvStrategy::HoWo] {
            let prog = Program::lower_riscv_uniform(&net, strategy, cores, 1);
            let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), cores);
            run_program(&net, &prog, &input, &mut ws, &mut out, &mut PulpBackend::new(&mut run));
            run.reset();
            let before = thread_allocs();
            run_program(&net, &prog, &input, &mut ws, &mut out, &mut PulpBackend::new(&mut run));
            let after = thread_allocs();
            assert_eq!(
                after - before,
                0,
                "{strategy:?} x{cores}: run_program heap-allocated {} time(s)",
                after - before
            );
        }
    }
}

#[test]
fn arm_batched_interpretation_is_allocation_free() {
    // The batch-N hot path must uphold the same discipline as batch 1,
    // including partial batches served from a larger-capacity program +
    // arena (the resident-worker shape: one program, many batch sizes).
    let net = QuantizedCapsNet::random(configs::mnist(), 42);
    let mut rng = XorShift::new(5);
    let capacity = 8usize;
    let mut ws = net.config.workspace_batched(capacity);
    for conv in [ArmConv::Basic, ArmConv::FastWithFallback] {
        let prog = Program::lower_arm_uniform(&net, conv, capacity);
        for batch in [1usize, 3, capacity] {
            let inputs = rng.i8_vec(batch * net.config.input_len());
            let mut out = vec![0i8; batch * net.config.output_len()];
            run_program_batched(
                &net, &prog, &inputs, batch, &mut ws, &mut out,
                &mut ArmBackend::new(&mut NullMeter),
            );
            let before = thread_allocs();
            run_program_batched(
                &net, &prog, &inputs, batch, &mut ws, &mut out,
                &mut ArmBackend::new(&mut NullMeter),
            );
            let after = thread_allocs();
            assert_eq!(
                after - before,
                0,
                "batch {batch} {conv:?}: run_program_batched heap-allocated {} time(s)",
                after - before
            );
        }
    }
}

#[test]
fn riscv_batched_interpretation_is_allocation_free() {
    let net = QuantizedCapsNet::random(configs::cifar10(), 42);
    let mut rng = XorShift::new(6);
    let batch = 4usize;
    let inputs = rng.i8_vec(batch * net.config.input_len());
    let mut ws = net.config.workspace_batched(batch);
    let mut out = vec![0i8; batch * net.config.output_len()];
    for cores in [1usize, 8] {
        for strategy in [PulpConvStrategy::Co, PulpConvStrategy::Ho, PulpConvStrategy::HoWo] {
            let prog = Program::lower_riscv_uniform(&net, strategy, cores, batch);
            let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), cores);
            run_program_batched(
                &net, &prog, &inputs, batch, &mut ws, &mut out, &mut PulpBackend::new(&mut run),
            );
            run.reset();
            let before = thread_allocs();
            run_program_batched(
                &net, &prog, &inputs, batch, &mut ws, &mut out, &mut PulpBackend::new(&mut run),
            );
            let after = thread_allocs();
            assert_eq!(
                after - before,
                0,
                "{strategy:?} x{cores}: run_program_batched heap-allocated {} time(s)",
                after - before
            );
        }
    }
}

#[test]
fn riscv_worker_loop_is_allocation_free_with_mixed_split_schedule() {
    // The riscv pooled-serving worker loop body (fault-fate lookup → pack →
    // interpret the compiled batched program → classify) must allocate zero
    // bytes after arena setup — including partial final batches and a plan
    // schedule that mixes per-layer core splits (each layer closes its own
    // meter section). The `FaultPlan` consultations mirror the
    // fault-tolerant control plane: fault bookkeeping rides the hot path as
    // pure `Copy` lookups, every mutable health transition stays outside it.
    use capsnet_edge::coordinator::{BatchFate, Fault, FaultPlan};
    use capsnet_edge::kernels::conv::PulpConvStrategy as S;
    use capsnet_edge::model::{PulpLayerExec, RiscvSchedule};
    let net = QuantizedCapsNet::random(configs::cifar10(), 42);
    let mut rng = XorShift::new(7);
    let capacity = 4usize;
    let in_len = net.config.input_len();
    let out_len = net.config.output_len();
    let n_conv = net.convs.len() + 1;
    let schedule = RiscvSchedule {
        conv: (0..n_conv)
            .map(|i| PulpLayerExec {
                strategy: [S::HoWo, S::Co, S::Ho][i % 3],
                cores: [8usize, 4, 1][i % 3],
            })
            .collect(),
        caps: (0..net.caps.len()).map(|i| [2usize, 8][i % 2]).collect(),
    };
    // Resident worker state, allocated/lowered once (mirrors
    // Fleet::serve_control_impl: the program and the fault plan are built
    // before the pool starts and shared read-only).
    let prog = Program::lower_riscv(&net, &schedule, capacity);
    let faults = FaultPlan {
        faults: vec![
            Fault::Flaky { device: 1, every: 3 },
            Fault::Die { device: 2, after_requests: 100 },
            Fault::LatencySpike { device: 0, factor: 4.0, from: 2, count: 2 },
        ],
    };
    let mut ws = net.config.workspace_batched(capacity);
    let mut packed = rng.i8_vec(capacity * in_len);
    let mut out = vec![0i8; capacity * out_len];
    let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
    let inputs = rng.i8_vec(capacity * in_len);
    // warm-up
    run.reset();
    run_program_batched(
        &net, &prog, &inputs, capacity, &mut ws, &mut out, &mut PulpBackend::new(&mut run),
    );
    let before = thread_allocs();
    let mut seq = 0u64;
    for batch in [capacity, 2, 1] {
        // The worker's per-assignment fault consultation (allocation-free).
        let fate = faults.fate(0, seq, batch);
        let _factor = faults.latency_factor(0, seq, batch);
        seq += batch as u64;
        if fate != BatchFate::Serve {
            continue; // device 0 only spikes, so every batch executes
        }
        packed[..batch * in_len].copy_from_slice(&inputs[..batch * in_len]);
        run.reset();
        run_program_batched(
            &net,
            &prog,
            &packed[..batch * in_len],
            batch,
            &mut ws,
            &mut out[..batch * out_len],
            &mut PulpBackend::new(&mut run),
        );
        for img_out in out[..batch * out_len].chunks_exact(out_len) {
            let _ = net.classify(img_out);
        }
    }
    assert_eq!(thread_allocs() - before, 0, "riscv worker loop allocated");
}

#[test]
fn traced_worker_loop_is_allocation_free_with_tracing_enabled() {
    // The observability acceptance bound: the pooled worker loop with
    // tracing ENABLED — per-op span recording inside the interpreter plus
    // the worker's execute span per batch — allocates zero bytes after
    // sink construction. The sink is a preallocated ring: *building* it
    // may allocate, *recording* into it may not, so the traced loop body
    // is exactly as heap-quiet as the untraced one.
    use capsnet_edge::coordinator::{BatchFate, Fault, FaultPlan};
    use capsnet_edge::exec::run_program_batched_traced;
    use capsnet_edge::obs::{ExecOutcome, SpanKind, SpanRecord, TraceSink, REQ_NONE};
    let net = QuantizedCapsNet::random(configs::cifar10(), 42);
    let mut rng = XorShift::new(8);
    let capacity = 4usize;
    let in_len = net.config.input_len();
    let out_len = net.config.output_len();
    let prog = Program::lower_riscv_uniform(&net, PulpConvStrategy::HoWo, 8, capacity);
    let faults = FaultPlan { faults: vec![Fault::Flaky { device: 1, every: 3 }] };
    let mut ws = net.config.workspace_batched(capacity);
    let mut packed = rng.i8_vec(capacity * in_len);
    let mut out = vec![0i8; capacity * out_len];
    let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
    let inputs = rng.i8_vec(capacity * in_len);
    // Sized for the warm-up pass plus all three loop batches, so nothing
    // wraps (a wrap would be allocation-free too, but zero drops lets the
    // totality assertions below hold).
    let mut sink = TraceSink::with_capacity((prog.ops().len() + 1) * 4);
    // warm-up
    run.reset();
    run_program_batched_traced(
        &net, &prog, &inputs, capacity, &mut ws, &mut out,
        &mut PulpBackend::new(&mut run), &mut sink,
    );
    let before = thread_allocs();
    let mut seq = 0u64;
    let mut batches_run = 0usize;
    for batch in [capacity, 2, 1] {
        let fate = faults.fate(0, seq, batch);
        seq += batch as u64;
        if fate != BatchFate::Serve {
            continue; // only device 1 is flaky, so every batch executes
        }
        packed[..batch * in_len].copy_from_slice(&inputs[..batch * in_len]);
        run.reset();
        run_program_batched_traced(
            &net,
            &prog,
            &packed[..batch * in_len],
            batch,
            &mut ws,
            &mut out[..batch * out_len],
            &mut PulpBackend::new(&mut run),
            &mut sink,
        );
        // The worker's execute span closes the batch's [ops..., execute]
        // sink group — recording it rides the same hot path.
        sink.record(SpanRecord {
            kind: SpanKind::Execute {
                n: batch as u16,
                outcome: ExecOutcome::Served,
                attempt: 0,
            },
            t0_us: seq * 100,
            t1_us: seq * 100 + 50,
            req: REQ_NONE,
            device: 0,
            pool: 0,
        });
        for img_out in out[..batch * out_len].chunks_exact(out_len) {
            let _ = net.classify(img_out);
        }
        batches_run += 1;
    }
    assert_eq!(thread_allocs() - before, 0, "traced worker loop allocated");
    assert_eq!(batches_run, 3);
    assert_eq!(
        sink.len(),
        (prog.ops().len() + 1) * 3 + prog.ops().len(),
        "one op span per program op per run, plus one execute span per batch"
    );
    assert_eq!(sink.dropped(), 0);
}

#[test]
fn simd_backend_interpretation_is_allocation_free() {
    // The vectorized host backend serves the same hot path as the scalar
    // backends: constructing it sizes its packing pool (deployment time —
    // may allocate), interpreting through it may not, for batch 1 and for
    // partial batches from a larger-capacity program + arena. This is the
    // "packing buffers keep the interpreter zero-alloc" half of the SIMD
    // backend's contract (bit-identity is the conformance tier's half).
    use capsnet_edge::exec::SimdBackend;
    let net = QuantizedCapsNet::random(configs::mnist(), 42);
    let mut rng = XorShift::new(9);
    let capacity = 4usize;
    let mut ws = net.config.workspace_batched(capacity);
    let prog = Program::lower_arm_uniform(&net, ArmConv::FastWithFallback, capacity);
    let mut simd = SimdBackend::for_config(&net.config, capacity);
    for batch in [1usize, 3, capacity] {
        let inputs = rng.i8_vec(batch * net.config.input_len());
        let mut out = vec![0i8; batch * net.config.output_len()];
        run_program_batched(&net, &prog, &inputs, batch, &mut ws, &mut out, &mut simd);
        let before = thread_allocs();
        run_program_batched(&net, &prog, &inputs, batch, &mut ws, &mut out, &mut simd);
        let after = thread_allocs();
        assert_eq!(
            after - before,
            0,
            "batch {batch}: simd run_program_batched heap-allocated {} time(s)",
            after - before
        );
    }
    // The pool-less fallback (classic scalar kernels) is hot-path too.
    let mut fallback = SimdBackend::new();
    let inputs = rng.i8_vec(net.config.input_len());
    let mut out = vec![0i8; net.config.output_len()];
    run_program(&net, &prog, &inputs, &mut ws, &mut out, &mut fallback);
    let before = thread_allocs();
    run_program(&net, &prog, &inputs, &mut ws, &mut out, &mut fallback);
    assert_eq!(thread_allocs() - before, 0, "pool-less simd fallback allocated");
}

#[test]
fn calibrator_sweep_is_allocation_free() {
    // The workspace-arena'd quant/calibration path: after Calibrator
    // construction (which lowers its programs), the per-image quantize →
    // interpret → classify loop must not touch the heap.
    use capsnet_edge::quant::{Calibrator, RangeTracker};
    let net = QuantizedCapsNet::random(configs::mnist(), 9);
    let mut cal = Calibrator::new(&net);
    let img = vec![0.25f32; net.config.input_len()];
    let mut tracker = RangeTracker::new();
    // warm-up
    let _ = cal.classify_arm(&net, &img, ArmConv::FastWithFallback);
    let before = thread_allocs();
    for _ in 0..3 {
        let _ = cal.classify_arm(&net, &img, ArmConv::FastWithFallback);
        cal.observe_outputs(&mut tracker, 7);
    }
    assert_eq!(thread_allocs() - before, 0, "calibrator sweep allocated");
}

#[test]
fn batched_calibrator_sweep_is_allocation_free() {
    // The batched-arena calibration sweep (ROADMAP follow-on from PR 2):
    // after construction, the quantize-batch → batched-interpret →
    // range-observe loop must not touch the heap — including partial
    // batches served from the batch-capacity arena.
    use capsnet_edge::quant::{Calibrator, RangeTracker};
    let net = QuantizedCapsNet::random(configs::mnist(), 11);
    let mut cal = Calibrator::new_batched(&net, 4);
    let imgs: Vec<Vec<f32>> =
        (0..4).map(|i| vec![0.1 * (i + 1) as f32; net.config.input_len()]).collect();
    let refs: Vec<&[f32]> = imgs.iter().map(|i| i.as_slice()).collect();
    let mut tracker = RangeTracker::new();
    // warm-up
    let _ = cal.infer_arm_batch(&net, &refs, ArmConv::FastWithFallback);
    let before = thread_allocs();
    for batch in [4usize, 2, 4, 1] {
        let _ = cal.infer_arm_batch(&net, &refs[..batch], ArmConv::FastWithFallback);
        cal.observe_outputs(&mut tracker, 7);
    }
    assert_eq!(thread_allocs() - before, 0, "batched calibrator sweep allocated");
    assert!(tracker.count() > 0);
}

#[test]
fn compatibility_wrappers_lower_per_call_and_trip_the_counter() {
    // Sanity in both directions: the counter does count, and the
    // `forward_*` compatibility wrappers (which lower a program per call)
    // are deliberately *outside* the zero-alloc guarantee — serving paths
    // hold pre-lowered programs instead.
    let net = QuantizedCapsNet::random(configs::cifar10(), 5);
    let mut rng = XorShift::new(4);
    let input = rng.i8_vec(net.config.input_len());
    let before = thread_allocs();
    let out = net.forward_arm(&input, ArmConv::Basic, &mut NullMeter);
    assert!(thread_allocs() > before, "counting allocator not counting");
    assert_eq!(out.len(), net.config.output_len());
}

#[test]
fn approx_program_interpretation_is_allocation_free_on_all_backends() {
    // The approximate-routing program is exactly as heap-quiet as the
    // exact one: the reciprocal/isqrt lookup tables are const statics in
    // rodata — owned before the program ever runs, never built per call —
    // and the approx kernels add no buffers. Covered on all three backends
    // (scalar Arm, scalar PULP under a mixed-split schedule, SIMD host),
    // batched, and with request tracing enabled on the PULP path.
    use capsnet_edge::exec::{run_program_batched_traced, Nonlinearity, SimdBackend};
    use capsnet_edge::model::RiscvSchedule;
    use capsnet_edge::obs::TraceSink;
    let net = QuantizedCapsNet::random(configs::cifar10(), 42);
    let mut rng = XorShift::new(12);
    let capacity = 4usize;
    let batch = 3usize; // partial batch from the capacity-4 arena
    let inputs = rng.i8_vec(batch * net.config.input_len());
    let mut ws = net.config.workspace_batched(capacity);
    let mut out = vec![0i8; batch * net.config.output_len()];
    let nl = vec![Nonlinearity::Approx; net.caps.len()];

    // Scalar Arm, metered.
    let sched = vec![ArmConv::FastWithFallback; net.convs.len() + 1];
    let prog = Program::lower_arm_nl(&net, &sched, &nl, capacity);
    let mut cc = CycleCounter::new(CostModel::cortex_m4());
    run_program_batched(&net, &prog, &inputs, batch, &mut ws, &mut out, &mut ArmBackend::new(&mut cc));
    let before = thread_allocs();
    run_program_batched(&net, &prog, &inputs, batch, &mut ws, &mut out, &mut ArmBackend::new(&mut cc));
    assert_eq!(thread_allocs() - before, 0, "arm approx batched allocated");

    // SIMD host backend, packed pool + pool-less fallback.
    let mut simd = SimdBackend::for_config(&net.config, capacity);
    run_program_batched(&net, &prog, &inputs, batch, &mut ws, &mut out, &mut simd);
    let before = thread_allocs();
    run_program_batched(&net, &prog, &inputs, batch, &mut ws, &mut out, &mut simd);
    assert_eq!(thread_allocs() - before, 0, "simd approx batched allocated");
    let mut fallback = SimdBackend::new();
    run_program_batched(&net, &prog, &inputs, batch, &mut ws, &mut out, &mut fallback);
    let before = thread_allocs();
    run_program_batched(&net, &prog, &inputs, batch, &mut ws, &mut out, &mut fallback);
    assert_eq!(thread_allocs() - before, 0, "pool-less simd approx batched allocated");

    // Scalar PULP under a mixed-split schedule, traced: the approx split
    // kernels close per-core sections and record op spans without heap use.
    let mut rsched =
        RiscvSchedule::uniform(PulpConvStrategy::HoWo, 8, net.convs.len(), net.caps.len());
    for (i, c) in rsched.caps.iter_mut().enumerate() {
        *c = [2usize, 8][i % 2];
    }
    let rprog = Program::lower_riscv_nl(&net, &rsched, &nl, capacity);
    let mut run = ClusterRun::new(&CostModel::gap8_cluster_core(), 8);
    let mut sink = TraceSink::with_capacity((rprog.ops().len() + 1) * 2);
    run_program_batched_traced(
        &net, &rprog, &inputs, batch, &mut ws, &mut out,
        &mut PulpBackend::new(&mut run), &mut sink,
    );
    run.reset();
    let before = thread_allocs();
    run_program_batched_traced(
        &net, &rprog, &inputs, batch, &mut ws, &mut out,
        &mut PulpBackend::new(&mut run), &mut sink,
    );
    assert_eq!(thread_allocs() - before, 0, "riscv approx traced batched allocated");
    assert_eq!(sink.dropped(), 0);
}
