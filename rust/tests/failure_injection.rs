//! Failure injection: corrupted artifacts, malformed configs, and boundary
//! conditions must produce clean errors, never panics or silent garbage.

use capsnet_edge::dataset::EvalSet;
use capsnet_edge::formats::{Archive, JsonValue, Tensor};
use capsnet_edge::model::{configs, CapsNetConfig, QuantizedCapsNet};
use capsnet_edge::testing::prop::{Prop, XorShift};

fn temp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("capsnet_failinj");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn truncated_cnq_rejected_at_every_length() {
    let net = QuantizedCapsNet::random(configs::cifar10(), 1);
    let bytes = net.to_archive().to_bytes();
    // Every strict prefix must fail to parse as an archive (or, if the
    // container happens to parse, fail model validation).
    let mut rng = XorShift::new(3);
    for _ in 0..200 {
        let cut = rng.range(0, bytes.len() - 1);
        match Archive::from_bytes(&bytes[..cut]) {
            Err(_) => {}
            Ok(archive) => {
                assert!(
                    QuantizedCapsNet::from_archive(&archive).is_err(),
                    "truncated archive at {cut} bytes loaded as a model"
                );
            }
        }
    }
}

#[test]
fn bitflipped_config_json_never_panics() {
    let net = QuantizedCapsNet::random(configs::mnist(), 2);
    let bytes = net.to_archive().to_bytes();
    Prop::new("bitflips never panic", 300).run(|rng| {
        let mut corrupted = bytes.clone();
        let idx = rng.range(0, corrupted.len() - 1);
        corrupted[idx] ^= 1 << rng.range(0, 7);
        // Either parse error or a loadable archive; loading the model may
        // fail or succeed (a weight bitflip is valid data) — must not panic.
        if let Ok(a) = Archive::from_bytes(&corrupted) {
            let _ = QuantizedCapsNet::from_archive(&a);
        }
    });
}

#[test]
fn missing_tensor_entries_reported_by_name() {
    let net = QuantizedCapsNet::random(configs::mnist(), 3);
    for victim in ["pcap.w", "caps0.w", "conv0.bias_shift", "input_qn"] {
        let mut a = Archive::new();
        for (name, t) in net.to_archive().iter() {
            if name != victim {
                a.insert(name, t.clone());
            }
        }
        let err = QuantizedCapsNet::from_archive(&a).unwrap_err().to_string();
        assert!(err.contains(victim), "error for missing {victim} was: {err}");
    }
}

#[test]
fn negative_shift_rejected() {
    let net = QuantizedCapsNet::random(configs::mnist(), 4);
    let mut a = net.to_archive();
    a.insert("conv0.out_shift", Tensor::I32 { dims: vec![1], data: vec![-3] });
    let err = QuantizedCapsNet::from_archive(&a).unwrap_err().to_string();
    assert!(err.contains("negative") || err.contains("non-negative"), "{err}");
}

#[test]
fn config_json_validation() {
    // structurally valid JSON, semantically broken configs
    let bad = [
        r#"{"name":"x","input":[28,28],"conv_layers":[],"pcap":{"num_caps":1,"cap_dim":1,"kernel":1,"stride":1},"caps_layers":[]}"#, // input not 3D
        r#"{"input":[28,28,1],"conv_layers":[],"pcap":{},"caps_layers":[]}"#, // missing name
        r#"{"name":"x","input":[28,28,1],"conv_layers":[{"filters":-2,"kernel":3,"stride":1}],"pcap":{"num_caps":1,"cap_dim":1,"kernel":1,"stride":1},"caps_layers":[]}"#, // negative filters
    ];
    for src in bad {
        let v = JsonValue::parse(src).unwrap();
        assert!(CapsNetConfig::from_json(&v).is_err(), "accepted: {src}");
    }
}

#[test]
fn evalset_shape_mismatches_rejected() {
    let mut a = Archive::new();
    a.insert("images", Tensor::F32 { dims: vec![3, 4, 4, 1], data: vec![0.0; 48] });
    a.insert("labels", Tensor::I32 { dims: vec![2], data: vec![0, 1] }); // count mismatch
    assert!(EvalSet::from_archive(&a).is_err());

    let mut a = Archive::new();
    a.insert("images", Tensor::I8 { dims: vec![2, 4, 4, 1], data: vec![0; 32] }); // wrong dtype
    a.insert("labels", Tensor::I32 { dims: vec![2], data: vec![0, 1] });
    assert!(EvalSet::from_archive(&a).is_err());
}

#[test]
fn archive_load_missing_file_has_path_in_error() {
    let p = temp_path("definitely_missing.npt");
    let err = Archive::load(&p).unwrap_err().to_string();
    assert!(err.contains("definitely_missing"), "{err}");
}

#[test]
fn zero_length_input_image_panics_cleanly() {
    let net = QuantizedCapsNet::random(configs::mnist(), 5);
    let r = std::panic::catch_unwind(|| {
        net.forward_arm(&[], capsnet_edge::model::ArmConv::Basic, &mut capsnet_edge::isa::NullMeter)
    });
    assert!(r.is_err(), "empty input accepted");
}

#[test]
fn malformed_plan_core_splits_rejected_without_half_applying() {
    // Malformed plans (core split > board cores, non-power-of-two split,
    // split declared for an Arm board) must be rejected by apply_plan
    // without half-applying — the device keeps serving with its prior
    // schedule, bit-identically.
    use capsnet_edge::coordinator::Device;
    use capsnet_edge::isa::Board;
    use capsnet_edge::plan::{plan_deployment, PlanOptions};
    use std::sync::Arc;

    let model = Arc::new(QuantizedCapsNet::random(configs::cifar10(), 7));
    let mut dev = Device::deploy(0, Board::gapuino(), model.clone()).unwrap();
    let good = plan_deployment(&model.config, &dev.board, &PlanOptions::default());
    dev.apply_plan(&good).unwrap();
    let input = vec![5i8; model.config.input_len()];
    let before_out = dev.infer(&input);
    let before_cycles = dev.inference_cycles;

    for (tamper, cores) in [("exceeds cluster", 16usize), ("non-power-of-two", 3), ("zero", 0)] {
        let mut bad = good.clone();
        bad.layers[0].cores = cores;
        let err = dev.apply_plan(&bad);
        assert!(err.is_err(), "{tamper}: split {cores} accepted");
        assert!(dev.has_plan(), "{tamper}: rejection dropped the prior schedule");
        assert_eq!(dev.infer(&input), before_out, "{tamper}: prior schedule corrupted");
        assert_eq!(dev.inference_cycles, before_cycles, "{tamper}: latency half-applied");
    }

    // A core split declared for an Arm board is malformed outright.
    let mut arm_dev = Device::deploy(1, Board::stm32h755(), model.clone()).unwrap();
    let mut arm_plan = plan_deployment(&model.config, &arm_dev.board, &PlanOptions::default());
    arm_plan.layers[0].cores = 2;
    assert!(arm_dev.apply_plan(&arm_plan).is_err(), "arm split accepted");
    assert!(!arm_dev.has_plan(), "rejected arm plan half-applied");
    assert_eq!(arm_dev.infer(&input), before_out, "arm device schedule corrupted");
}

#[test]
fn malformed_plan_rejected_by_pooled_serving_not_panicking() {
    use capsnet_edge::coordinator::{Fleet, Request, RouterPolicy};
    use capsnet_edge::isa::Board;
    use capsnet_edge::plan::{plan_deployment, PlanOptions};
    use std::sync::Arc;

    let model = Arc::new(QuantizedCapsNet::random(configs::cifar10(), 9));
    let mut fleet = Fleet::new(RouterPolicy::RoundRobin);
    fleet.add_device(Board::gapuino(), model.clone()).unwrap();
    let requests: Vec<Request> = (0..3)
        .map(|i| Request {
            id: i as u64,
            arrival_ms: 0.0,
            input_q: vec![0i8; model.config.input_len()],
            label: None,
        })
        .collect();
    let mut bad = plan_deployment(&model.config, &Board::gapuino(), &PlanOptions::default());
    bad.layers[0].cores = 3;
    assert!(fleet.serve_planned(&requests, &bad, 2).is_err(), "non-pow2 split served");
    let mut too_wide = plan_deployment(&model.config, &Board::gapuino(), &PlanOptions::default());
    too_wide.layers[0].cores = 16;
    assert!(fleet.serve_planned(&requests, &too_wide, 2).is_err(), "16-core split served");
}

#[test]
fn model_weights_swapped_between_configs_rejected() {
    // mnist weights loaded under a cifar10 config header must fail size checks
    let mnist = QuantizedCapsNet::random(configs::mnist(), 6);
    let mut a = mnist.to_archive();
    let cfg = configs::cifar10().to_json().to_string_compact();
    a.insert("config.json", Tensor::U8 { dims: vec![cfg.len()], data: cfg.into_bytes() });
    assert!(QuantizedCapsNet::from_archive(&a).is_err());
}
