//! Failure injection: corrupted artifacts, malformed configs, and boundary
//! conditions must produce clean errors, never panics or silent garbage.

use capsnet_edge::dataset::EvalSet;
use capsnet_edge::formats::{Archive, JsonValue, Tensor};
use capsnet_edge::model::{configs, CapsNetConfig, QuantizedCapsNet};
use capsnet_edge::testing::prop::{Prop, XorShift};

fn temp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("capsnet_failinj");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn truncated_cnq_rejected_at_every_length() {
    let net = QuantizedCapsNet::random(configs::cifar10(), 1);
    let bytes = net.to_archive().to_bytes();
    // Every strict prefix must fail to parse as an archive (or, if the
    // container happens to parse, fail model validation).
    let mut rng = XorShift::new(3);
    for _ in 0..200 {
        let cut = rng.range(0, bytes.len() - 1);
        match Archive::from_bytes(&bytes[..cut]) {
            Err(_) => {}
            Ok(archive) => {
                assert!(
                    QuantizedCapsNet::from_archive(&archive).is_err(),
                    "truncated archive at {cut} bytes loaded as a model"
                );
            }
        }
    }
}

#[test]
fn bitflipped_config_json_never_panics() {
    let net = QuantizedCapsNet::random(configs::mnist(), 2);
    let bytes = net.to_archive().to_bytes();
    Prop::new("bitflips never panic", 300).run(|rng| {
        let mut corrupted = bytes.clone();
        let idx = rng.range(0, corrupted.len() - 1);
        corrupted[idx] ^= 1 << rng.range(0, 7);
        // Either parse error or a loadable archive; loading the model may
        // fail or succeed (a weight bitflip is valid data) — must not panic.
        if let Ok(a) = Archive::from_bytes(&corrupted) {
            let _ = QuantizedCapsNet::from_archive(&a);
        }
    });
}

#[test]
fn missing_tensor_entries_reported_by_name() {
    let net = QuantizedCapsNet::random(configs::mnist(), 3);
    for victim in ["pcap.w", "caps0.w", "conv0.bias_shift", "input_qn"] {
        let mut a = Archive::new();
        for (name, t) in net.to_archive().iter() {
            if name != victim {
                a.insert(name, t.clone());
            }
        }
        let err = QuantizedCapsNet::from_archive(&a).unwrap_err().to_string();
        assert!(err.contains(victim), "error for missing {victim} was: {err}");
    }
}

#[test]
fn negative_shift_rejected() {
    let net = QuantizedCapsNet::random(configs::mnist(), 4);
    let mut a = net.to_archive();
    a.insert("conv0.out_shift", Tensor::I32 { dims: vec![1], data: vec![-3] });
    let err = QuantizedCapsNet::from_archive(&a).unwrap_err().to_string();
    assert!(err.contains("negative") || err.contains("non-negative"), "{err}");
}

#[test]
fn config_json_validation() {
    // structurally valid JSON, semantically broken configs
    let bad = [
        r#"{"name":"x","input":[28,28],"conv_layers":[],"pcap":{"num_caps":1,"cap_dim":1,"kernel":1,"stride":1},"caps_layers":[]}"#, // input not 3D
        r#"{"input":[28,28,1],"conv_layers":[],"pcap":{},"caps_layers":[]}"#, // missing name
        r#"{"name":"x","input":[28,28,1],"conv_layers":[{"filters":-2,"kernel":3,"stride":1}],"pcap":{"num_caps":1,"cap_dim":1,"kernel":1,"stride":1},"caps_layers":[]}"#, // negative filters
    ];
    for src in bad {
        let v = JsonValue::parse(src).unwrap();
        assert!(CapsNetConfig::from_json(&v).is_err(), "accepted: {src}");
    }
}

#[test]
fn evalset_shape_mismatches_rejected() {
    let mut a = Archive::new();
    a.insert("images", Tensor::F32 { dims: vec![3, 4, 4, 1], data: vec![0.0; 48] });
    a.insert("labels", Tensor::I32 { dims: vec![2], data: vec![0, 1] }); // count mismatch
    assert!(EvalSet::from_archive(&a).is_err());

    let mut a = Archive::new();
    a.insert("images", Tensor::I8 { dims: vec![2, 4, 4, 1], data: vec![0; 32] }); // wrong dtype
    a.insert("labels", Tensor::I32 { dims: vec![2], data: vec![0, 1] });
    assert!(EvalSet::from_archive(&a).is_err());
}

#[test]
fn archive_load_missing_file_has_path_in_error() {
    let p = temp_path("definitely_missing.npt");
    let err = Archive::load(&p).unwrap_err().to_string();
    assert!(err.contains("definitely_missing"), "{err}");
}

#[test]
fn zero_length_input_image_panics_cleanly() {
    let net = QuantizedCapsNet::random(configs::mnist(), 5);
    let r = std::panic::catch_unwind(|| {
        net.forward_arm(&[], capsnet_edge::model::ArmConv::Basic, &mut capsnet_edge::isa::NullMeter)
    });
    assert!(r.is_err(), "empty input accepted");
}

#[test]
fn malformed_plan_core_splits_rejected_without_half_applying() {
    // Malformed plans (core split > board cores, non-power-of-two split,
    // split declared for an Arm board) must be rejected by apply_plan
    // without half-applying — the device keeps serving with its prior
    // schedule, bit-identically.
    use capsnet_edge::coordinator::Device;
    use capsnet_edge::isa::Board;
    use capsnet_edge::plan::{plan_deployment, PlanOptions};
    use std::sync::Arc;

    let model = Arc::new(QuantizedCapsNet::random(configs::cifar10(), 7));
    let mut dev = Device::deploy(0, Board::gapuino(), model.clone()).unwrap();
    let good = plan_deployment(&model.config, &dev.board, &PlanOptions::default());
    dev.apply_plan(&good).unwrap();
    let input = vec![5i8; model.config.input_len()];
    let before_out = dev.infer(&input);
    let before_cycles = dev.inference_cycles;

    for (tamper, cores) in [("exceeds cluster", 16usize), ("non-power-of-two", 3), ("zero", 0)] {
        let mut bad = good.clone();
        bad.layers[0].cores = cores;
        let err = dev.apply_plan(&bad);
        assert!(err.is_err(), "{tamper}: split {cores} accepted");
        assert!(dev.has_plan(), "{tamper}: rejection dropped the prior schedule");
        assert_eq!(dev.infer(&input), before_out, "{tamper}: prior schedule corrupted");
        assert_eq!(dev.inference_cycles, before_cycles, "{tamper}: latency half-applied");
    }

    // A core split declared for an Arm board is malformed outright.
    let mut arm_dev = Device::deploy(1, Board::stm32h755(), model.clone()).unwrap();
    let mut arm_plan = plan_deployment(&model.config, &arm_dev.board, &PlanOptions::default());
    arm_plan.layers[0].cores = 2;
    assert!(arm_dev.apply_plan(&arm_plan).is_err(), "arm split accepted");
    assert!(!arm_dev.has_plan(), "rejected arm plan half-applied");
    assert_eq!(arm_dev.infer(&input), before_out, "arm device schedule corrupted");
}

#[test]
fn malformed_plan_rejected_by_pooled_serving_not_panicking() {
    use capsnet_edge::coordinator::{Fleet, Request, RouterPolicy};
    use capsnet_edge::isa::Board;
    use capsnet_edge::plan::{plan_deployment, PlanOptions};
    use std::sync::Arc;

    let model = Arc::new(QuantizedCapsNet::random(configs::cifar10(), 9));
    let mut fleet = Fleet::new(RouterPolicy::RoundRobin);
    fleet.add_device(Board::gapuino(), model.clone()).unwrap();
    let requests: Vec<Request> = (0..3)
        .map(|i| Request {
            id: i as u64,
            arrival_ms: 0.0,
            input_q: vec![0i8; model.config.input_len()],
            label: None,
        })
        .collect();
    let mut bad = plan_deployment(&model.config, &Board::gapuino(), &PlanOptions::default());
    bad.layers[0].cores = 3;
    assert!(fleet.serve_planned(&requests, &bad, 2).is_err(), "non-pow2 split served");
    let mut too_wide = plan_deployment(&model.config, &Board::gapuino(), &PlanOptions::default());
    too_wide.layers[0].cores = 16;
    assert!(fleet.serve_planned(&requests, &too_wide, 2).is_err(), "16-core split served");
}

#[test]
fn model_weights_swapped_between_configs_rejected() {
    // mnist weights loaded under a cifar10 config header must fail size checks
    let mnist = QuantizedCapsNet::random(configs::mnist(), 6);
    let mut a = mnist.to_archive();
    let cfg = configs::cifar10().to_json().to_string_compact();
    a.insert("config.json", Tensor::U8 { dims: vec![cfg.len()], data: cfg.into_bytes() });
    assert!(QuantizedCapsNet::from_archive(&a).is_err());
}

// ---------------------------------------------------------------------------
// Fault-tolerant control plane: injected board faults against the pooled
// serving loop (registry, retries, quarantine, admission control).
// ---------------------------------------------------------------------------

mod control_plane {
    use capsnet_edge::coordinator::{
        BatchPolicy, Fault, FaultPlan, Fleet, HealthPolicy, HealthState, RejectReason, Request,
        RouterPolicy, ServeConfig,
    };
    use capsnet_edge::isa::Board;
    use capsnet_edge::model::{configs, QuantizedCapsNet};
    use capsnet_edge::testing::prop::XorShift;
    use std::sync::Arc;

    fn fleet(boards: &[Board], seed: u64) -> (Fleet, Arc<QuantizedCapsNet>) {
        let model = Arc::new(QuantizedCapsNet::random(configs::cifar10(), seed));
        let mut f = Fleet::new(RouterPolicy::RoundRobin);
        for b in boards {
            f.add_device(b.clone(), model.clone()).unwrap();
        }
        (f, model)
    }

    fn requests(model: &QuantizedCapsNet, n: usize, seed: u64) -> Vec<Request> {
        let mut rng = XorShift::new(seed);
        (0..n)
            .map(|i| Request {
                id: i as u64,
                arrival_ms: 0.0,
                input_q: rng.i8_vec(model.config.input_len()),
                label: None,
            })
            .collect()
    }

    /// Acceptance criterion: under a mid-batch board death (plus a flaky
    /// board) with retry budget ≥ 1, every non-exhausted request's output
    /// is bit-identical to the fault-free run.
    #[test]
    fn fault_recovery_is_bit_identical_to_fault_free_run() {
        let (f, model) = fleet(&[Board::stm32h755(), Board::stm32h755()], 21);
        let reqs = requests(&model, 12, 22);
        let policy = BatchPolicy::new(1e9, 4);
        let clean = f.serve_pooled(&reqs, policy, 2).unwrap();
        assert!(clean.faults.is_zero());
        assert!(clean.rejections.is_empty());

        // Retries advance the surviving device's sequence numbers, so the
        // periodic flake can re-fire on a re-dispatched batch — the budget
        // must cover a short unlucky chain, not just one failure.
        let cfg = ServeConfig {
            retry_budget: 10,
            faults: FaultPlan {
                faults: vec![
                    Fault::Die { device: 0, after_requests: 2 },
                    Fault::Flaky { device: 1, every: 5 },
                ],
            },
            ..ServeConfig::default()
        };
        let faulted = f.serve_pooled_with(&reqs, policy, 2, &cfg).unwrap();
        assert!(
            faulted.rejections.is_empty(),
            "budget must absorb one death + flakiness: {:?}",
            faulted.rejections
        );
        assert_eq!(faulted.outputs.len(), reqs.len(), "no request lost or duplicated");
        assert_eq!(
            faulted.outputs_by_id(),
            clean.outputs_by_id(),
            "recovered outputs must be bit-identical to the fault-free run"
        );
        assert_eq!(faulted.faults.deaths, 1);
        assert!(faulted.faults.retries >= 1);
        assert_eq!(faulted.health[0], HealthState::Dead);
    }

    /// Same bit-identity across a *mixed-ISA* fleet: work lost on the
    /// RISC-V pool re-dispatches onto the Arm pool (and vice versa) without
    /// changing a single output bit — cross-ISA conformance in action.
    #[test]
    fn mixed_isa_recovery_is_bit_identical_across_pools() {
        let (f, model) = fleet(&[Board::gapuino(), Board::stm32h755()], 23);
        let reqs = requests(&model, 10, 24);
        let policy = BatchPolicy::new(1e9, 2);
        let clean = f.serve_pooled(&reqs, policy, 2).unwrap();
        assert!(clean.rejections.is_empty());

        // Kill the GAP-8 pool outright: everything must land on the Arm pool.
        let cfg = ServeConfig {
            faults: FaultPlan {
                faults: vec![Fault::Die { device: 0, after_requests: 0 }],
            },
            ..ServeConfig::default()
        };
        let faulted = f.serve_pooled_with(&reqs, policy, 2, &cfg).unwrap();
        assert!(faulted.rejections.is_empty(), "{:?}", faulted.rejections);
        assert_eq!(faulted.outputs_by_id(), clean.outputs_by_id());
        assert_eq!(faulted.health[0], HealthState::Dead);
        assert_eq!(faulted.health[1], HealthState::Healthy);
    }

    /// A flaky board quarantines under its failure streak, then a probe
    /// readmits it (to Degraded, not Healthy) and it finishes the run —
    /// still bit-clean. Single-device fleet: with a healthy peer around,
    /// health-aware routing would starve the flaky board before it could
    /// ever streak into quarantine.
    #[test]
    fn failure_streak_quarantines_and_probe_readmits() {
        let (f, model) = fleet(&[Board::stm32h755()], 25);
        let reqs = requests(&model, 16, 26);
        let policy = BatchPolicy::none(); // batch 1: every request is a batch
        let clean = f.serve_pooled(&reqs, policy, 1).unwrap();

        // Every second request fails; quarantine on the first failure so
        // the quarantine → probe → readmit cycle exercises every round.
        let cfg = ServeConfig {
            retry_budget: 10,
            faults: FaultPlan { faults: vec![Fault::Flaky { device: 0, every: 2 }] },
            health: HealthPolicy { quarantine_after: 1, ..HealthPolicy::default() },
            ..ServeConfig::default()
        };
        let faulted = f.serve_pooled_with(&reqs, policy, 1, &cfg).unwrap();
        assert!(faulted.rejections.is_empty(), "{:?}", faulted.rejections);
        assert_eq!(faulted.outputs_by_id(), clean.outputs_by_id());
        assert!(faulted.faults.quarantined >= 1, "streak never quarantined");
        assert!(faulted.faults.probes >= 1, "no readmission probe issued");
        assert!(faulted.faults.readmitted >= 1, "probe never readmitted the board");
        assert!(faulted.faults.transient_failures >= 3);
    }

    /// Exhausting the retry budget surfaces typed rejections — never a
    /// panic, never a silent drop — and the report still serves everything
    /// the surviving boards could.
    #[test]
    fn retry_exhaustion_yields_typed_rejections() {
        let (f, model) = fleet(&[Board::stm32h755(), Board::stm32h755()], 27);
        let reqs = requests(&model, 8, 28);
        // Both boards die before serving anything.
        let all_dead = FaultPlan {
            faults: vec![
                Fault::Die { device: 0, after_requests: 0 },
                Fault::Die { device: 1, after_requests: 0 },
            ],
        };
        // Budget 0: the lost work exhausts immediately → RetriesExhausted.
        let cfg = ServeConfig {
            retry_budget: 0,
            faults: all_dead.clone(),
            ..ServeConfig::default()
        };
        let report = f.serve_pooled_with(&reqs, BatchPolicy::new(1e9, 4), 2, &cfg).unwrap();
        assert!(report.outputs.is_empty(), "dead fleet served {}", report.outputs.len());
        assert_eq!(report.rejections.len(), reqs.len(), "every request typed-rejected");
        for r in &report.rejections {
            assert!(
                matches!(r.reason, RejectReason::RetriesExhausted { attempts: 1 }),
                "unexpected reason {:?}",
                r.reason
            );
        }
        assert_eq!(report.faults.deaths, 2);
        assert_eq!(report.faults.exhausted_requests, reqs.len() as u64);
        assert!(report.health.iter().all(|h| *h == HealthState::Dead));

        // Budget 1: the retry is granted, but by then nobody dispatchable
        // is left → NoHealthyDevice. Either way: typed, total, no panic.
        let cfg = ServeConfig { retry_budget: 1, faults: all_dead, ..ServeConfig::default() };
        let report = f.serve_pooled_with(&reqs, BatchPolicy::new(1e9, 4), 2, &cfg).unwrap();
        assert!(report.outputs.is_empty());
        assert_eq!(report.rejections.len(), reqs.len());
        assert!(report
            .rejections
            .iter()
            .all(|r| r.reason == RejectReason::NoHealthyDevice));
        assert!(report.faults.retries >= 1, "budget 1 re-dispatches before giving up");
    }

    /// Admission control: a queue-depth watermark sheds the overflow of a
    /// burst as `Backpressure` rejections instead of queueing unboundedly;
    /// admitted requests still serve bit-identically.
    #[test]
    fn queue_watermark_sheds_burst_as_backpressure() {
        let (f, model) = fleet(&[Board::stm32h755()], 29);
        let reqs = requests(&model, 12, 30);
        // All 12 arrive at t=0 on one device with watermark 4: one batch of
        // 4 is admitted, the rest shed (virtual completions are all later).
        let cfg = ServeConfig {
            queue_watermark: Some(4),
            ..ServeConfig::default()
        };
        let report = f.serve_pooled_with(&reqs, BatchPolicy::new(1e9, 4), 1, &cfg).unwrap();
        assert_eq!(report.outputs.len(), 4, "watermark admits one full batch");
        assert_eq!(report.rejections.len(), 8);
        assert!(report
            .rejections
            .iter()
            .all(|r| r.reason == RejectReason::Backpressure));
        assert_eq!(report.faults.backpressure_rejections, 8);
        // Admitted outputs match the unthrottled run's first batch bits.
        let clean = f.serve_pooled(&reqs, BatchPolicy::new(1e9, 4), 1).unwrap();
        let clean_by_id = clean.outputs_by_id();
        for (id, out) in report.outputs_by_id() {
            assert_eq!(out, clean_by_id[id as usize].1, "req {id}");
        }
    }

    /// A plan/model mismatch reported at attach time quarantines the board
    /// before it serves anything; with no probe path back (mismatch probes
    /// fail), the healthy board carries the whole run.
    #[test]
    fn plan_mismatch_on_attach_quarantines_device() {
        let (f, model) = fleet(&[Board::stm32h755(), Board::stm32h755()], 31);
        let reqs = requests(&model, 6, 32);
        let cfg = ServeConfig {
            faults: FaultPlan { faults: vec![Fault::PlanMismatch { device: 0 }] },
            ..ServeConfig::default()
        };
        let report = f.serve_pooled_with(&reqs, BatchPolicy::new(1e9, 2), 2, &cfg).unwrap();
        assert!(report.rejections.is_empty(), "{:?}", report.rejections);
        assert_eq!(report.outputs.len(), 6);
        assert_eq!(report.health[0], HealthState::Quarantined, "mismatch never readmitted");
        assert_eq!(report.faults.quarantined, 1);
        assert_eq!(
            report.outputs_by_id(),
            f.serve_pooled(&reqs, BatchPolicy::new(1e9, 2), 2).unwrap().outputs_by_id()
        );
    }

    /// Latency spikes feed the registry's outlier detector: a sustained
    /// spike degrades the board, but outputs are unaffected.
    #[test]
    fn latency_spikes_degrade_without_corrupting_outputs() {
        let (f, model) = fleet(&[Board::stm32h755()], 33);
        let reqs = requests(&model, 8, 34);
        let policy = BatchPolicy::none();
        let cfg = ServeConfig {
            faults: FaultPlan {
                faults: vec![Fault::LatencySpike {
                    device: 0,
                    factor: 10.0,
                    from: 0,
                    count: 100,
                }],
            },
            ..ServeConfig::default()
        };
        let report = f.serve_pooled_with(&reqs, policy, 1, &cfg).unwrap();
        assert_eq!(report.outputs.len(), 8);
        assert!(report.faults.latency_outliers >= 3);
        assert_eq!(report.health[0], HealthState::Degraded);
        assert_eq!(
            report.outputs_by_id(),
            f.serve_pooled(&reqs, policy, 1).unwrap().outputs_by_id()
        );
    }

    /// Planned serving threads the same control plane: a mid-batch death
    /// under a deployment plan recovers bit-identically too.
    #[test]
    fn planned_serving_recovers_from_death_bit_identically() {
        use capsnet_edge::plan::{plan_deployment, PlanOptions};
        let (f, model) = fleet(&[Board::gapuino(), Board::gapuino()], 35);
        let reqs = requests(&model, 9, 36);
        let plan = plan_deployment(
            &model.config,
            &Board::gapuino(),
            &PlanOptions { batch_capacity: 4, slo_ms: 1e9, ..PlanOptions::default() },
        );
        let clean = f.serve_planned(&reqs, &plan, 2).unwrap();
        let cfg = ServeConfig {
            faults: FaultPlan {
                faults: vec![Fault::Die { device: 1, after_requests: 1 }],
            },
            ..ServeConfig::default()
        };
        let faulted = f.serve_planned_with(&reqs, &plan, 2, &cfg).unwrap();
        assert!(faulted.rejections.is_empty(), "{:?}", faulted.rejections);
        assert_eq!(faulted.outputs_by_id(), clean.outputs_by_id());
        assert_eq!(faulted.health[1], HealthState::Dead);
    }
}
