//! Fleet integration: real quantized models served over the heterogeneous
//! simulated fleet — latency ordering, policy behaviour, accuracy.
//!
//! Skips gracefully when artifacts are absent.

use capsnet_edge::coordinator::{request_stream, Fleet, RouterPolicy};
use capsnet_edge::dataset::EvalSet;
use capsnet_edge::isa::Board;
use capsnet_edge::model::QuantizedCapsNet;
use std::path::Path;
use std::sync::Arc;

fn load_mnist() -> Option<(Arc<QuantizedCapsNet>, EvalSet)> {
    let m = Path::new("artifacts/models/mnist.cnq");
    let e = Path::new("artifacts/data/mnist_eval.npt");
    if !m.exists() || !e.exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return None;
    }
    Some((
        Arc::new(QuantizedCapsNet::load(m).unwrap()),
        EvalSet::load(e).unwrap(),
    ))
}

#[test]
fn device_latencies_follow_paper_ordering() {
    let Some((net, _)) = load_mnist() else { return };
    let mut fleet = Fleet::new(RouterPolicy::EarliestFinish);
    for b in Board::all() {
        fleet.add_device(b, net.clone()).unwrap();
    }
    let ms: Vec<f64> = fleet.devices.iter().map(|d| d.inference_ms).collect();
    // Order: [M4, M7, M33, GAP-8]. Paper: GAP-8 octa fastest by far; M7 is
    // the fastest Arm in wall-clock (480 MHz).
    let (m4, m7, m33, gap8) = (ms[0], ms[1], ms[2], ms[3]);
    assert!(gap8 < m7 && m7 < m4, "latencies {ms:?}");
    assert!(gap8 < m33, "latencies {ms:?}");
    // GAP-8 vs M4 gap is large (paper §5.2.2: "almost two orders of magnitude"
    // in cycles; in ms the clock ratio narrows it)
    assert!(m4 / gap8 > 10.0, "m4/gap8 = {:.1}", m4 / gap8);
}

#[test]
fn fleet_serves_eval_set_with_high_accuracy() {
    let Some((net, eval)) = load_mnist() else { return };
    let mut fleet = Fleet::new(RouterPolicy::EarliestFinish);
    for b in Board::all() {
        fleet.add_device(b, net.clone()).unwrap();
    }
    let requests = request_stream(&net, &eval, 64, 5.0);
    let (results, rejections, metrics) = fleet.simulate(&requests).unwrap();
    assert_eq!(results.len(), 64);
    assert!(rejections.is_empty());
    assert!(metrics.accuracy > 0.9, "fleet accuracy {:.3}", metrics.accuracy);
    assert!(metrics.throughput_rps > 0.0);
    // every device with work shows nonzero utilization
    let busy: Vec<_> = metrics.per_device.iter().filter(|(_, n, _)| *n > 0).collect();
    assert!(!busy.is_empty());
}

#[test]
fn earliest_finish_shifts_load_to_fast_devices() {
    let Some((net, eval)) = load_mnist() else { return };
    let mut fleet = Fleet::new(RouterPolicy::EarliestFinish);
    for b in Board::all() {
        fleet.add_device(b, net.clone()).unwrap();
    }
    fleet.execute = false;
    for d in fleet.devices.iter_mut() {
        d.queue_limit = usize::MAX; // isolate routing behaviour from backpressure
    }
    // saturating arrival rate → load distributes by speed
    let requests = request_stream(&net, &eval, 400, 0.0);
    let (_, _, metrics) = fleet.simulate(&requests).unwrap();
    let completed: Vec<u64> = metrics.per_device.iter().map(|&(_, n, _)| n).collect();
    let gap8 = completed[3];
    let m4 = completed[0];
    assert!(
        gap8 > 5 * m4.max(1),
        "earliest-finish should load the GAP-8 most: {completed:?}"
    );
}

#[test]
fn policies_trade_latency_for_fairness() {
    let Some((net, eval)) = load_mnist() else { return };
    let requests_for = |_p| request_stream(&net, &eval, 200, 1.0);
    let mut makespans = Vec::new();
    for policy in RouterPolicy::all() {
        let mut fleet = Fleet::new(policy);
        for b in Board::all() {
            fleet.add_device(b, net.clone()).unwrap();
        }
        fleet.execute = false;
        for d in fleet.devices.iter_mut() {
            d.queue_limit = usize::MAX;
        }
        let (_, _, m) = fleet.simulate(&requests_for(policy)).unwrap();
        makespans.push((policy.name(), m.makespan_ms));
    }
    let ef = makespans.iter().find(|(n, _)| *n == "earliest-finish").unwrap().1;
    let rr = makespans.iter().find(|(n, _)| *n == "round-robin").unwrap().1;
    assert!(ef <= rr + 1e-9, "{makespans:?}");
}

#[test]
fn threaded_serving_matches_simulation_outputs() {
    let Some((net, eval)) = load_mnist() else { return };
    let mut fleet = Fleet::new(RouterPolicy::RoundRobin);
    fleet.add_device(Board::stm32h755(), net.clone()).unwrap();
    fleet.add_device(Board::gapuino(), net.clone()).unwrap();
    let requests = request_stream(&net, &eval, 8, 10.0);
    let report = fleet.serve_threaded(&requests).unwrap();
    assert_eq!(report.latencies_us.len(), 8);
    assert!(report.rps > 0.5, "host throughput {}", report.rps);
}

#[test]
fn riscv_pooled_serving_matches_sequential_on_real_model() {
    // Satellite: on the real quantized MNIST model, an all-GAP-8 fleet's
    // pooled and plan-driven serving must be bit-identical to sequential
    // Device::infer_batch execution (partial tail batch included).
    use capsnet_edge::coordinator::BatchPolicy;
    use capsnet_edge::plan::{plan_deployment, PlanOptions};
    let Some((net, eval)) = load_mnist() else { return };
    let mut fleet = Fleet::new(RouterPolicy::RoundRobin);
    fleet.add_device(Board::gapuino(), net.clone()).unwrap();
    let requests = request_stream(&net, &eval, 11, 0.0);
    let inputs: Vec<&[i8]> = requests.iter().map(|r| r.input_q.as_slice()).collect();
    let expected = fleet.devices[0].infer_batch(&inputs);

    let report = fleet.serve_pooled(&requests, BatchPolicy::new(1e9, 4), 2).unwrap();
    for (k, (_, out)) in report.outputs_by_id().into_iter().enumerate() {
        assert_eq!(out, expected[k], "pooled req {k}");
    }

    let plan = plan_deployment(
        &net.config,
        &Board::gapuino(),
        &PlanOptions { batch_capacity: 4, slo_ms: 1e9, ..PlanOptions::default() },
    );
    let report = fleet.serve_planned(&requests, &plan, 2).unwrap();
    for (k, (_, out)) in report.outputs_by_id().into_iter().enumerate() {
        assert_eq!(out, expected[k], "planned req {k}");
    }
}
